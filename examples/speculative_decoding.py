"""Speculative decoding (paper §6.1): a draft model proposes K tokens, the
target verifies them in one pass — lossless for greedy decoding.

  PYTHONPATH=src python examples/speculative_decoding.py
"""
import sys

import numpy as np

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.models.params import init_params  # noqa: E402
from repro.serving.speculative import SpeculativeDecoder  # noqa: E402


def main():
    cfg = get_config("granite-3-8b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(7))
    draft_cfg = cfg.replace(num_layers=1, name="draft-1L")
    draft_params = init_params(draft_cfg, jax.random.PRNGKey(8))

    rng = np.random.default_rng(2)
    prompt = list(rng.integers(0, cfg.vocab_size, 12))
    for name, dc, dp in [("perfect draft (self)", cfg, params),
                         ("1-layer draft", draft_cfg, draft_params)]:
        spec = SpeculativeDecoder(cfg, params, dc, dp, k=4)
        out = spec.generate(prompt, 16)
        print(f"{name:22s}: acceptance={spec.stats.acceptance:5.1%} "
              f"target_passes={spec.stats.target_steps:2d} "
              f"tokens={out[:8]}...")


if __name__ == "__main__":
    main()
