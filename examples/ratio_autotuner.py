"""P/D ratio auto-adjustment (paper §3.3, Fig. 12): run a decode-heavy
workload on a bad ratio, watch the bottleneck monitor flag it, re-run on
the Eq.1 optimum and compare — then do the adjustment LIVE on real
engines: a ClusterFrontend deployed at a bad ratio flips idle nodes
between P and D roles at runtime until it reaches the optimum.

  PYTHONPATH=src python examples/ratio_autotuner.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.cluster_sim import ClusterSim, SimConfig, run_workload  # noqa: E402
from repro.core.perf_model import (BottleneckMonitor, InstanceProfile,  # noqa: E402
                                   optimal_ratio)
from repro.core.profiles import profile_for  # noqa: E402
from repro.core.requests import Scenario, WorkloadGenerator  # noqa: E402


def run_ratio(prof, sc, n_p, n_d, seed=4):
    gen = WorkloadGenerator([sc], base_rps=55.0, seed=seed)
    reqs = gen.arrivals(60.0)
    sim = ClusterSim(SimConfig(profile=prof), n_prefill=n_p, n_decode=n_d,
                     policy="ondemand", seed=seed)
    m = run_workload(sim, reqs, 90.0)
    mon = BottleneckMonitor(window=50)
    for r in sim.completed:
        mon.record(r.ttft, r.e2e)
    return m, mon


def main():
    prof = profile_for(get_config("pangu-38b"))
    sc = Scenario("demo/gen", "demo", 1024, 4, 256, 64, 320, 64,
                  slo_ttft=6.0)
    total = 12

    m_bad, mon = run_ratio(prof, sc, 8, 4)
    print(f"8P:4D  -> {m_bad['throughput_rps']:.1f} rps, "
          f"success {m_bad['success_rate']:.2f}, "
          f"monitor says: {mon.recommendation() or 'n/a'}")

    iprof = InstanceProfile(
        ttft_bs=prof.ttft(4 * (sc.prefix_len + sc.query_len_mean), 0),
        b_p=4, r_pre=0.6, tpot_bs=prof.tpot(16), b_d=16,
        gen_tokens=sc.out_tokens_mean, xi=0.02)
    n_p, n_d = optimal_ratio(iprof, total)
    print(f"Eq.1 optimum for this pattern: {n_p}P:{n_d}D")

    m_opt, _ = run_ratio(prof, sc, n_p, n_d)
    gain = (m_opt["throughput_rps"] / max(m_bad["throughput_rps"], 1e-9)
            - 1) * 100
    print(f"{n_p}P:{n_d}D -> {m_opt['throughput_rps']:.1f} rps, "
          f"success {m_opt['success_rate']:.2f}  (+{gain:.0f}% throughput)")

    live_adjustment()


def live_adjustment():
    """Runtime ratio adjustment on REAL engines: deploy 3P:1D against a
    decode-heavy Eq.1 profile and watch the adjuster flip nodes."""
    from repro.serving.cluster import ServeRequest
    from repro.serving.frontend import ClusterFrontend

    cfg = get_config("granite-3-8b").reduced()
    iprof = InstanceProfile(ttft_bs=0.1, b_p=4, r_pre=1.0, tpot_bs=0.05,
                            b_d=8, gen_tokens=100.0, xi=0.0)
    want = optimal_ratio(iprof, 4)
    fe = ClusterFrontend(cfg, topology={"demo/gen": (3, 1)},
                         adjust_ratio=True, adjust_interval=2,
                         profiles={"demo/gen": iprof})
    g = fe.groups["demo/gen"]
    print(f"live: deployed {g.ratio[0]}P:{g.ratio[1]}D, "
          f"Eq.1 wants {want[0]}P:{want[1]}D")
    rng = np.random.default_rng(0)
    reqs = [ServeRequest(rid=i, scenario="demo/gen",
                         tokens=list(rng.integers(0, cfg.vocab_size, 8)),
                         max_new_tokens=6) for i in range(6)]
    fe.run(reqs, max_ticks=60)
    for _ in range(8):      # idle ticks: let the adjuster converge
        fe.tick()
    for t, old, new, kind in g.flips:
        print(f"  t={float(t):7.3f}s: {kind}  {old} -> {new} "
              f"(re-registered in zookeeper)")
    print(f"live: final ratio {g.ratio[0]}P:{g.ratio[1]}D, "
          f"served {sum(r.done for r in reqs)}/{len(reqs)} during flips")


if __name__ == "__main__":
    main()
