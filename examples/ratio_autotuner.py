"""P/D ratio auto-adjustment (paper §3.3, Fig. 12): run a decode-heavy
workload on a bad ratio, watch the bottleneck monitor flag it, re-run on
the Eq.1 optimum and compare.

  PYTHONPATH=src python examples/ratio_autotuner.py
"""
import sys

sys.path.insert(0, "src")

from repro.configs import get_config  # noqa: E402
from repro.core.cluster_sim import ClusterSim, SimConfig, run_workload  # noqa: E402
from repro.core.perf_model import (BottleneckMonitor, InstanceProfile,  # noqa: E402
                                   optimal_ratio)
from repro.core.profiles import profile_for  # noqa: E402
from repro.core.requests import Scenario, WorkloadGenerator  # noqa: E402


def run_ratio(prof, sc, n_p, n_d, seed=4):
    gen = WorkloadGenerator([sc], base_rps=55.0, seed=seed)
    reqs = gen.arrivals(60.0)
    sim = ClusterSim(SimConfig(profile=prof), n_prefill=n_p, n_decode=n_d,
                     policy="ondemand", seed=seed)
    m = run_workload(sim, reqs, 90.0)
    mon = BottleneckMonitor(window=50)
    for r in sim.completed:
        mon.record(r.ttft, r.e2e)
    return m, mon


def main():
    prof = profile_for(get_config("pangu-38b"))
    sc = Scenario("demo/gen", "demo", 1024, 4, 256, 64, 320, 64,
                  slo_ttft=6.0)
    total = 12

    m_bad, mon = run_ratio(prof, sc, 8, 4)
    print(f"8P:4D  -> {m_bad['throughput_rps']:.1f} rps, "
          f"success {m_bad['success_rate']:.2f}, "
          f"monitor says: {mon.recommendation() or 'n/a'}")

    iprof = InstanceProfile(
        ttft_bs=prof.ttft(4 * (sc.prefix_len + sc.query_len_mean), 0),
        b_p=4, r_pre=0.6, tpot_bs=prof.tpot(16), b_d=16,
        gen_tokens=sc.out_tokens_mean, xi=0.02)
    n_p, n_d = optimal_ratio(iprof, total)
    print(f"Eq.1 optimum for this pattern: {n_p}P:{n_d}D")

    m_opt, _ = run_ratio(prof, sc, n_p, n_d)
    gain = (m_opt["throughput_rps"] / max(m_bad["throughput_rps"], 1e-9)
            - 1) * 100
    print(f"{n_p}P:{n_d}D -> {m_opt['throughput_rps']:.1f} rps, "
          f"success {m_opt['success_rate']:.2f}  (+{gain:.0f}% throughput)")


if __name__ == "__main__":
    main()
