"""Quickstart: serve a small model through the full disaggregated path.

  PYTHONPATH=src python examples/quickstart.py [--arch granite-3-8b]

What happens: prompts hit the gateway, an idle prefill accepts (busy ones
reject), the prompt's KVCache is gathered to a contiguous buffer, moved to
a decode instance's paged pool, RecvScatter'd back into blocks, and decode
streams tokens — all with real JAX compute on a reduced config.
"""
import argparse
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.configs import ALIASES, get_config  # noqa: E402
from repro.serving.cluster import MiniCluster, ServeRequest  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=sorted(ALIASES))
    a = ap.parse_args()
    cfg = get_config(a.arch).reduced()
    print(f"model: {cfg.name} ({cfg.num_layers}L d={cfg.d_model})")

    cluster = MiniCluster(cfg, n_prefill=1, n_decode=1)
    rng = np.random.default_rng(0)
    requests = [
        ServeRequest(rid=i,
                     tokens=list(rng.integers(0, cfg.vocab_size, 10 + i)),
                     max_new_tokens=8,
                     on_token=lambda t, i=i: print(f"  [sse rid={i}] {t}"))
        for i in range(3)
    ]
    cluster.run(requests, max_ticks=60)
    for r in requests:
        print(f"request {r.rid}: prompt[{len(r.tokens)}] -> {r.generated}")
    assert all(r.done for r in requests)
    print("ok")


if __name__ == "__main__":
    main()
