"""Train a ~100M-param llama-like model for a few hundred steps on CPU.

  PYTHONPATH=src python examples/train_tiny.py [--steps 300]

(The paper is a serving system — the serving driver in
disaggregated_serving.py is the primary end-to-end example; this exercises
the training substrate: data pipeline, AdamW, remat, checkpointing.)
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import main as train_main  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    a = ap.parse_args()
    # ~100M params: 12 layers x d512 over the minicpm vocab
    sys.exit(train_main([
        "--arch", "minicpm-2b", "--reduced",
        "--d-model", "512", "--layers", "12",
        "--steps", str(a.steps), "--batch", "8", "--seq", "256",
        "--lr", "1e-3", "--log-every", "25",
        "--save", "results/ckpt_tiny.npz",
    ]))


if __name__ == "__main__":
    main()
