"""Cluster-scale day-in-the-life: tidal traffic, group auto-scaling, fault
injection + minimum-cost recovery — the MLOps side of P/D-Serve (Fig. 13).

  PYTHONPATH=src python examples/cluster_scale_sim.py
"""
import sys

sys.path.insert(0, "src")

from repro.core.group import PDGroup  # noqa: E402
from repro.core.mlops import MLOps, NodeMonitor  # noqa: E402
from repro.core.requests import tidal_rate  # noqa: E402
from repro.core.zookeeper import MetaStore  # noqa: E402


def main():
    meta = MetaStore()
    group = PDGroup("svcA/chat#g0", "svcA/chat", meta)
    t = group.setup(0.0, n_prefill=2, n_decode=4)
    print(f"group serving at t={t:.0f}s; workflow:")
    for ev in group.timeline:
        print(f"  t={ev.t:7.1f}s {ev.step:14s} {ev.detail}")

    ml = MLOps(meta, NodeMonitor(seed=4, fault_rate_per_hour=0.03))
    events = []
    while t < 86400.0:
        act = ml.auto_scale(t, group, base_rps=40.0,
                            rps_capacity_per_pair=11.0)
        if act:
            events.append((t, act, group.ratio))
        for rec in ml.check_and_recover(t, group, dt_hours=0.5):
            events.append((t, f"recovered {rec.iid} "
                           f"({rec.level}, {rec.recovery_time:.0f}s)",
                           group.ratio))
        t += 1800.0

    print(f"\nday timeline ({len(events)} events):")
    for tt, what, ratio in events:
        hour = tt / 3600.0
        rate = tidal_rate(40.0, tt)
        print(f"  {hour:5.1f}h rate={rate:5.1f}rps  {what:44s} "
              f"ratio={ratio[0]}:{ratio[1]}")
    print(f"\nfaults recovered: {len(ml.faults)}; "
          f"scaling actions: {len(ml.scale_events)}")


if __name__ == "__main__":
    main()
