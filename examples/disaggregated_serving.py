"""End-to-end driver (the paper's kind): serve batched requests across a
multi-instance P/D group, comparing block-free vs block-fixed transfer and
showing gateway rejections + zookeeper metadata.

  PYTHONPATH=src python examples/disaggregated_serving.py
"""
import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config  # noqa: E402
from repro.core.transfer import LinkModel  # noqa: E402
from repro.serving.cluster import MiniCluster, ServeRequest  # noqa: E402


def workload(cfg, n, seed=1):
    rng = np.random.default_rng(seed)
    return [ServeRequest(rid=i,
                         tokens=list(rng.integers(0, cfg.vocab_size,
                                                  int(rng.integers(6, 24)))),
                         max_new_tokens=6)
            for i in range(n)]


def main():
    cfg = get_config("qwen2-moe-a2.7b").reduced()
    print(f"arch: {cfg.name} (MoE {cfg.moe.num_experts}e top-{cfg.moe.top_k})")
    for mode in ("block_free", "block_fixed"):
        mc = MiniCluster(cfg, n_prefill=2, n_decode=2, transfer_mode=mode,
                         link=LinkModel())
        reqs = workload(cfg, 10)
        t0 = time.time()
        mc.run(reqs, max_ticks=200)
        xf = mc.xfer.stats
        sim_d2d = float(np.mean([t.time_s for t in xf])) if xf else 0.0
        msgs = int(np.mean([t.n_msgs for t in xf])) if xf else 0
        print(f"  {mode:12s}: {sum(r.done for r in reqs)}/{len(reqs)} done, "
              f"wall {time.time()-t0:.1f}s, modeled D2D "
              f"{sim_d2d*1e3:.2f}ms over {msgs} msgs/transfer, "
              f"gateway rejections={mc.rejections}")
    # the zookeeper view of the group
    mc_meta = mc.meta
    print("zookeeper group g0:",
          {role: mc_meta.group_members("g0", role) for role in ("P", "D")})
    print("first instance RoCE IPs:",
          mc_meta.instances["P0"].roce_ips[:4], "...")


if __name__ == "__main__":
    main()
