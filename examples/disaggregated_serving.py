"""End-to-end driver (the paper's kind): serve a two-scenario workload
through the scenario-aware multi-group frontend (affinity routing +
cross-group fallback), then compare block-free vs block-fixed transfer
on the single-group MiniCluster shim.

  PYTHONPATH=src python examples/disaggregated_serving.py
"""
import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config  # noqa: E402
from repro.core.transfer import LinkModel  # noqa: E402
from repro.serving.cluster import MiniCluster, ServeRequest  # noqa: E402
from repro.serving.frontend import ClusterFrontend  # noqa: E402


def workload(cfg, n, seed=1, *, scenario="default", max_new=6, rid0=0):
    rng = np.random.default_rng(seed)
    return [ServeRequest(rid=rid0 + i, scenario=scenario,
                         tokens=list(rng.integers(0, cfg.vocab_size,
                                                  int(rng.integers(6, 24)))),
                         max_new_tokens=max_new)
            for i in range(n)]


def main():
    cfg = get_config("qwen2-moe-a2.7b").reduced()
    print(f"arch: {cfg.name} (MoE {cfg.moe.num_experts}e top-{cfg.moe.top_k})")

    # ---- scenario-aware multi-group frontend (paper §3.2 + §3.5)
    fe = ClusterFrontend(cfg, topology={"svcA/chat": (1, 1),
                                        "svcA/summ": (1, 1)},
                         link=LinkModel())
    reqs = (workload(cfg, 5, seed=2, scenario="svcA/chat")
            + workload(cfg, 5, seed=3, scenario="svcA/summ", rid0=100))
    t0 = time.time()
    fe.run(reqs, max_ticks=200)
    print(f"multi-group: {sum(r.done for r in reqs)}/{len(reqs)} done, "
          f"wall {time.time()-t0:.1f}s")
    for sc, st in fe.stats().items():
        print(f"  {sc:12s}: {int(st['n_p'])}P:{int(st['n_d'])}D "
              f"accepted={int(st['accepted'])} "
              f"rejections={int(st['rejections'])}")
    print("zookeeper groups:",
          {gid: {role: fe.meta.group_members(gid, role)
                 for role in ("P", "D")}
           for gid in fe.meta.groups})

    # ---- block-level prefix reuse on the real path (paper §2.2.1)
    cfg_d = get_config("granite-3-8b").reduced()
    fe = ClusterFrontend(cfg_d, topology={"default": (1, 1)},
                         prefill_kwargs={"block_size": 4},
                         decode_kwargs={"block_size": 4})
    rng = np.random.default_rng(4)
    shared = list(map(int, rng.integers(0, cfg_d.vocab_size, 16)))
    for i in range(4):       # same 16-token prefix, distinct suffixes
        tail = list(map(int, rng.integers(0, cfg_d.vocab_size, 5)))
        fe.run([ServeRequest(rid=200 + i, tokens=shared + tail,
                             max_new_tokens=3)], max_ticks=60)
    pf = fe.groups["default"].prefix_stats()
    print(f"prefix reuse: hit_rate={pf['hit_rate']:.0%} "
          f"reused={int(pf['reused_tokens'])}tok "
          f"computed={int(pf['compute_tokens'])}tok "
          f"(cold would compute {4 * 21}tok), "
          f"cow={int(pf['cow_copies'])} evictions={int(pf['evictions'])}")

    # ---- transfer-path comparison on the single-group shim: the
    # overlapped layer-wise pipeline (default) vs the blocking modes
    for label, kw in (("overlapped", dict(overlap_transfer=True)),
                      ("block_free", dict(overlap_transfer=False,
                                          transfer_mode="block_free")),
                      ("block_fixed", dict(overlap_transfer=False,
                                           transfer_mode="block_fixed"))):
        mc = MiniCluster(cfg, n_prefill=2, n_decode=2, link=LinkModel(),
                         **kw)
        reqs = workload(cfg, 10)
        t0 = time.time()
        mc.run(reqs, max_ticks=200)
        if label == "overlapped":
            tf = mc.frontend.groups["default"].transfer_stats()
            sim_d2d = tf["admission_wait_mean_s"]
            msgs = int(tf["link_msgs"] / max(tf["jobs_admitted"], 1))
        else:
            xf = mc.xfer.stats
            sim_d2d = float(np.mean([t.time_s for t in xf])) if xf else 0.0
            msgs = int(np.mean([t.n_msgs for t in xf])) if xf else 0
        print(f"  {label:12s}: {sum(r.done for r in reqs)}/{len(reqs)} done, "
              f"wall {time.time()-t0:.1f}s, D2D admission stall "
              f"{sim_d2d*1e3:.2f}ms over {msgs} msgs/transfer, "
              f"gateway rejections={mc.rejections}")
    print("first instance RoCE IPs:",
          mc.meta.instances["P0"].roce_ips[:4], "...")


if __name__ == "__main__":
    main()
