"""DynaServe-style chunked prefill on the real engines (ISSUE 10).

The elasticity lever behind prefill absorption: a long prompt is split
into aligned chunks threaded through ``run_suffix`` (stitched KV +
recurrent state), so an idle decode node can absorb prefill work a few
chunks at a time. The bar is TOKEN IDENTITY per config family: the
chunked first token AND the full greedy decode stream must equal the
monolithic prefill's (KV is additionally bitwise for the attn-free /
hybrid families, whose recurrent scan fixes the geometry; attention
KV under per-chunk padded geometry may differ in ulps, which the pinned
decode stream proves immaterial)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_params
from parity_utils import BS, POOL_KW, admit
from repro.serving.engine import DecodeEngine, PrefillEngine
from repro.serving.kvcache import PagedKVPool

FAMILIES = ["granite-3-8b", "qwen2-moe-a2.7b", "mamba2-2.7b",
            "jamba-1.5-large-398b"]
STATEFUL = {"mamba2-2.7b", "jamba-1.5-large-398b"}


def _cfg_params(arch):
    cfg, params = reduced_params(arch)
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  dispatch="sorted"))
    return cfg, params


def _prompt(cfg, n, seed=11):
    rng = np.random.default_rng(seed)
    return list(map(int, rng.integers(0, cfg.vocab_size, n)))


# ------------------------------------------------------------- bounds

@pytest.mark.parametrize("arch", FAMILIES)
def test_chunk_bounds_alignment(arch):
    # every interior cut is a legal aligned run_suffix boundary, the
    # step never shrinks below the alignment, and the tail keeps >= 1
    # token — for every family's own prefix_align
    cfg, params = _cfg_params(arch)
    eng = PrefillEngine(cfg, params)
    a = max(eng.prefix_align, 1)
    for n in (7, 16, 17, 40, 123):
        for chunk_tokens in (8, 16, 32):
            cuts = eng.chunk_bounds(n, chunk_tokens)
            assert cuts == sorted(set(cuts))
            for c in cuts:
                assert 0 < c < n and c % a == 0
            step = max(a, (chunk_tokens // a) * a)
            assert all(c % step == 0 for c in cuts)


# ----------------------------------------------- first-token identity

@pytest.mark.parametrize("arch", FAMILIES)
def test_chunked_first_token_matches_monolithic(arch):
    cfg, params = _cfg_params(arch)
    eng = PrefillEngine(cfg, params)
    tokens = _prompt(cfg, 37)
    mono = eng.run([tokens])[0]
    chunked = eng.run_chunked(tokens, chunk_tokens=16)
    assert chunked.first_token == mono.first_token
    assert chunked.prompt_len == mono.prompt_len
    if arch in STATEFUL:
        # the recurrent scan fixes per-chunk geometry: state (and KV,
        # when present) is bitwise
        if mono.mamba_state is not None:
            eq = jax.tree_util.tree_map(jnp.array_equal,
                                        chunked.mamba_state,
                                        mono.mamba_state)
            assert all(bool(x) for x in jax.tree_util.tree_leaves(eq))
        if mono.k is not None:
            assert jnp.array_equal(chunked.k, mono.k)
            assert jnp.array_equal(chunked.v, mono.v)


@pytest.mark.parametrize("chunk_tokens", [8, 16, 24])
def test_chunk_size_invariance(chunk_tokens):
    cfg, params = _cfg_params("granite-3-8b")
    eng = PrefillEngine(cfg, params)
    tokens = _prompt(cfg, 41, seed=13)
    mono = eng.run([tokens])[0]
    out = eng.run_chunked(tokens, chunk_tokens=chunk_tokens)
    assert out.first_token == mono.first_token


# --------------------------------------------- full-stream identity

@pytest.mark.parametrize("arch", FAMILIES)
def test_chunked_decode_stream_matches_monolithic(arch):
    """The acceptance bar: greedy decode from a chunked prefill emits
    the exact token stream of a decode from the monolithic prefill."""
    cfg, params = _cfg_params(arch)
    eng = PrefillEngine(cfg, params)
    tokens = _prompt(cfg, 29, seed=7)
    streams = []
    for mode in ("mono", "chunked"):
        out = (eng.run([tokens])[0] if mode == "mono"
               else eng.run_chunked(tokens, chunk_tokens=12))
        pool = PagedKVPool(cfg, **POOL_KW)
        dec = DecodeEngine(cfg, params, pool, max_slots=4)
        admit(pool, dec, 0, out)
        toks = [out.first_token]
        for _ in range(8):
            emitted = dec.step()
            toks.extend(emitted[r] for r in sorted(emitted))
        streams.append(toks)
        assert pool.invariant_ok()
    assert streams[0] == streams[1]


def test_iter_chunks_counts():
    cfg, params = _cfg_params("granite-3-8b")
    eng = PrefillEngine(cfg, params)
    tokens = _prompt(cfg, 50, seed=3)
    seen = list(eng.iter_chunks(tokens, chunk_tokens=16))
    assert sum(n for n, _ in seen) == len(tokens)
    assert len(seen) == len(eng.chunk_bounds(len(tokens), 16)) + 1
    # engine-side telemetry
    assert eng.chunked_prefills >= 1
    assert eng.chunked_chunks >= len(seen)
