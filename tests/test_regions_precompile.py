"""§3.7 multi-region routing + disaster recovery, and §3.2 pre-compiled
model store (AOT serialize/load)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_params
from repro.configs import get_config
from repro.core.cluster_sim import ClusterSim, SimConfig
from repro.core.profiles import profile_for
from repro.core.regions import Region, ServiceRouter
from repro.core.requests import Scenario, WorkloadGenerator
from repro.launch.precompile import ArtifactStore
from repro.models.config import ShapeConfig


def _region(name, prof, scenario, seed):
    sim = ClusterSim(SimConfig(profile=prof), n_prefill=2, n_decode=4,
                     policy="ondemand", seed=seed)
    return Region(name, {scenario: sim})


def test_router_balances_by_capacity():
    prof = profile_for(get_config("pangu-38b"))
    sc = Scenario("svc/x", "svc", 512, 2, 128, 32, 64, 16, 3.0)
    r1 = _region("r1", prof, sc.name, 1)
    r2 = _region("r2", prof, sc.name, 2)
    router = ServiceRouter([r1, r2], seed=0)
    gen = WorkloadGenerator([sc], base_rps=12, seed=3)
    m = router.run(gen.arrivals(40.0), 60.0)
    assert m["success_rate"] > 0.95
    # both regions took meaningful traffic
    assert min(m["routed"].values()) > 0.25 * max(m["routed"].values())


def test_region_failure_fails_over():
    prof = profile_for(get_config("pangu-38b"))
    sc = Scenario("svc/x", "svc", 512, 2, 128, 32, 64, 16, 3.0)
    r1 = _region("r1", prof, sc.name, 1)
    r2 = _region("r2", prof, sc.name, 2)
    router = ServiceRouter([r1, r2], seed=0)
    gen = WorkloadGenerator([sc], base_rps=10, seed=4)
    m = router.run(gen.arrivals(40.0), 70.0, fail_at=20.0, fail_region="r1")
    # service continues: late traffic all lands in r2, nothing dropped
    assert m["dropped"] == 0
    assert m["success_rate"] > 0.9
    late_r1 = [r for r in r1.sims[sc.name].completed if r.arrival >= 20.0]
    assert not late_r1, "failed region must not receive post-failure traffic"


def test_all_regions_down_drops_cleanly():
    prof = profile_for(get_config("pangu-38b"))
    sc = Scenario("svc/x", "svc", 512, 2, 128, 32, 64, 16, 3.0)
    r1 = _region("r1", prof, sc.name, 1)
    router = ServiceRouter([r1], seed=0)
    router.fail_region("r1")
    gen = WorkloadGenerator([sc], base_rps=5, seed=5)
    m = router.run(gen.arrivals(10.0), 20.0)
    assert m["completed"] == 0 and m["dropped"] > 0


# ------------------------------------------------------------ precompile
def test_precompiled_store_roundtrip(tmp_path):
    cfg, params = reduced_params("granite-3-8b")
    from repro.models.caches import zeros_cache
    shape = ShapeConfig("t", 32, 2, "decode")
    store = ArtifactStore(str(tmp_path))
    cache = zeros_cache(cfg, 2, 32)
    tok = jnp.zeros((2,), jnp.int32)
    abstract = (jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                             params),
                jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                             cache),
                jax.ShapeDtypeStruct((2,), jnp.int32))
    man = store.precompile("granite/decode", cfg, shape, abstract)
    assert man["size_bytes"] > 0
    fn, man2 = store.load("granite/decode")
    assert man2["load_s"] >= 0
    nxt, new_cache = fn(params, cache, tok)
    # must equal the jit path exactly
    from repro.models.steps import make_serve_step
    want, _ = jax.jit(make_serve_step(cfg))(params,
                                            zeros_cache(cfg, 2, 32), tok)
    np.testing.assert_array_equal(np.asarray(nxt), np.asarray(want))
    assert "granite_decode" in store.available()
