"""End-to-end system behaviour: train a tiny model until loss drops, and
serve through the full P/D data path (paper's two step kinds)."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import reduced_params
from repro.data import SyntheticLM
from repro.models.steps import make_train_step
from repro.serving.cluster import MiniCluster, ServeRequest
from repro.training.optimizer import AdamWConfig, adamw_init


def test_training_reduces_loss():
    cfg, params = reduced_params("minicpm-2b")
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3)))
    opt = adamw_init(params)
    data = SyntheticLM(cfg.vocab_size, 64, 8, seed=0)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.3, (first, last)


def test_serve_disaggregated_batched_requests():
    cfg, params = reduced_params("qwen2-moe-a2.7b")
    mc = MiniCluster(cfg, n_prefill=2, n_decode=2, params=params)
    rng = np.random.default_rng(1)
    reqs = [ServeRequest(rid=i,
                         tokens=list(rng.integers(0, cfg.vocab_size,
                                                  int(rng.integers(4, 12)))),
                         max_new_tokens=4)
            for i in range(8)]
    done = mc.run(reqs, max_ticks=120)
    assert all(r.done for r in done)
    assert all(len(r.generated) == 5 for r in done)
