"""TransferScheduler invariants under randomized interleavings
(hypothesis): across begin / partial pump / conflict retry / node
failure / drain / requeue orderings,

  * no dst block is ever leaked or double-freed (pool accounting stays
    exact, and releasing a completed request restores every pool to
    fully-free),
  * each link carries at most ONE in-flight message (send intervals on
    a link never overlap),
  * every completed transfer is byte-identical to a direct copy.
"""
from types import SimpleNamespace

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from conftest import reduced_params
from repro.core.transfer import LinkModel
from repro.serving.kvcache import PagedKVPool
from repro.serving.transfer_sched import TransferScheduler

NB = 64
BS = 4


def _mk_dst(cfg, iid):
    return SimpleNamespace(iid=iid, draining=False,
                           pool=PagedKVPool(cfg, num_blocks=NB,
                                            block_size=BS))


def _assert_links_serial(sched):
    for link in sched.links.values():
        hist = sorted(link.history)
        assert all(a[1] <= b[0] + 1e-12 for a, b in zip(hist, hist[1:])), \
            link.key


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_no_leak_no_double_free_and_serial_links(data):
    cfg, _ = reduced_params("granite-3-8b")
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    dsts = [_mk_dst(cfg, "D0"), _mk_dst(cfg, "D1")]
    healthy = {"D0", "D1"}

    def pick(job):
        cands = [d for d in dsts
                 if d.iid in healthy and not d.draining]
        return cands[0] if cands else None

    link = LinkModel(hops=data.draw(st.sampled_from([1, 3])),
                     conflict_prob=data.draw(st.sampled_from([0.0, 0.5])))
    sched = TransferScheduler(link, seed=data.draw(st.integers(0, 999)),
                              pick_dst=pick)
    expected = {}                       # rid -> (tokens, want bytes)
    jobs = []
    failed_once = False
    fail_t = float("inf")
    for step in range(data.draw(st.integers(2, 10))):
        act = data.draw(st.sampled_from(["begin", "pump", "fail",
                                         "drain", "undrain"]))
        if act == "begin":
            rid = 100 + step
            tokens = data.draw(st.integers(1, 18))
            L = sum(1 for k in cfg.layer_kinds() if k == "attn")
            k = jnp.asarray(rng.normal(size=(L, tokens, cfg.kv_dim)),
                            jnp.float32)
            v = jnp.asarray(rng.normal(size=(L, tokens, cfg.kv_dim)),
                            jnp.float32)
            out = SimpleNamespace(k=k, v=v, prompt_len=tokens,
                                  mamba_state={}, cross=None)
            req = SimpleNamespace(rid=rid, max_new_tokens=2)
            dst = pick(None)
            if dst is None:
                continue
            jobs.append(sched.begin(
                req, out, src_iid=data.draw(st.sampled_from(["P0", "P1"])),
                dst=dst, t_start=sched.now,
                compute_s=data.draw(st.sampled_from([0.0, 0.01]))))
            expected[rid] = (tokens, np.concatenate(
                [np.asarray(k), np.asarray(v)], -1))
        elif act == "pump":
            sched.pump(sched.now + data.draw(st.floats(0.0, 0.02)))
        elif act == "fail" and not failed_once:
            failed_once = True
            fail_t = sched.now
            healthy.discard("D0")
            sched.fail_node("D0")
        elif act == "drain":
            # D1 stays up so a target always exists eventually
            dsts[0].draining = True
        elif act == "undrain":
            dsts[0].draining = False
        _assert_links_serial(sched)
        for d in dsts:
            assert d.pool.invariant_ok(), d.iid
    # drive to completion: every job must land somewhere healthy
    dsts[0].draining = False
    for _ in range(100_000):
        if sched.idle():
            break
        nxt = sched.next_event()
        if nxt is None:                  # waiting_dst: capacity returned
            sched.pump(sched.now + 1.0)
            if sched.next_event() is None and not sched.idle():
                raise AssertionError("scheduler stalled with no target")
            continue
        sched.pump(nxt)
    assert sched.idle()
    _assert_links_serial(sched)
    for job in jobs:
        assert job.state == "admitted"
        tokens, want = expected[job.rid]
        got = np.asarray(job.dst.pool.read_tokens(
            job.dst_blocks[:job.n_kv_blocks], tokens))
        np.testing.assert_array_equal(got, want)
        # jobs still in flight when D0 failed must have moved off it
        # (jobs admitted before the failure may legitimately stay)
        if failed_once and job.admitted_t > fail_t:
            assert job.dst.iid in healthy
    # releasing every admitted request must return BOTH pools to fully
    # free — any leaked or double-freed block breaks the accounting
    for job in jobs:
        job.dst.pool.release(job.rid)
    for d in dsts:
        assert d.pool.invariant_ok()
        assert d.pool.free_blocks == NB, d.iid
