"""Universal bucketed prefill (PR 5): EVERY config family right-pads
ragged batches to power-of-two length buckets through the one shared
jitted forward — exactly.

The pad-invariance contract under test:
  * bucketed output is token-identical to the exact-length path, per
    family, including the KV written for real positions;
  * SSM/hybrid recurrent state (mamba2, jamba) is bit-identical to the
    exact-length run (zero-dt pads are state no-ops; conv tails are
    gathered at the valid boundary);
  * capacity-dispatch MoE (qwen2-moe, deepseek-moe) routes identically
    under padding — window-local capacity with a valid-count threshold
    and pads force-routed to the null slot — even when experts overflow
    and really drop tokens;
  * warm prefix-reuse admissions bucket BOTH the suffix and the prefix
    KV (traced q_offset), so retraces are O(bucket pairs), never
    O(distinct prefix lengths).
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from hypothesis import given, settings, strategies as st

from conftest import ALL_ARCHS, reduced_params
from parity_utils import make_frames as _frames, make_prompts as _prompts, \
    outputs_equal as _outputs_equal, serve_sequential, prefill_node
from repro.kernels import ref
from repro.serving.engine import PrefillEngine, prefill_compile_count

RAGGED_LENS = (5, 13, 8)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_bucketed_matches_exact_per_family(arch):
    """Ragged + warm-prefix workload per family: bucketed == exact
    (tokens, KV, mamba recurrent-state bit-identity, MoE routing), with
    the compile count pinned to the bucket set, not the length set."""
    cfg, params = reduced_params(arch)
    rng = np.random.default_rng(9)
    prompts = _prompts(cfg, rng, RAGGED_LENS)
    frames = _frames(cfg, rng, len(prompts))
    exact = PrefillEngine(cfg, params, bucket_prefill=False)
    bucketed = PrefillEngine(cfg, params, bucket_prefill=True)
    assert not hasattr(bucketed, "supports_bucketing")  # gate DELETED
    o_e = exact.run(prompts, frames=frames)
    c0 = prefill_compile_count()
    o_b = bucketed.run(prompts, frames=frames)
    bucket_compiles = prefill_compile_count() - c0
    for a, b in zip(o_e, o_b):
        _outputs_equal(a, b)
    # accounting stays exact; padding is ledgered separately
    assert exact.compute_tokens == bucketed.compute_tokens \
        == sum(RAGGED_LENS)
    assert bucketed.padded_tokens > exact.padded_tokens
    assert bucket_compiles <= 1          # one (batch, bucket) shape
    # a SECOND ragged wave with all-new lengths in the same bucket must
    # not retrace (O(num_buckets), not O(distinct lengths))
    c1 = prefill_compile_count()
    wave2 = _prompts(cfg, rng, (7, 12, 6))
    frames2 = _frames(cfg, rng, 3)
    o_w = bucketed.run(wave2, frames=frames2)
    assert prefill_compile_count() == c1
    assert bucketed.bucket_hits >= 1     # telemetry saw the shape reuse
    ref_w = exact.run(wave2, frames=frames2)
    for a, b in zip(ref_w, o_w):
        assert a.first_token == b.first_token
    # warm prefix-reuse leg (attention stacks): suffix-only prefill with
    # a BUCKETED prefix must match the cold run and reuse the program.
    # SSM/hybrid families need a boundary state snapshot for warm runs —
    # their warm parity (incl. bucketing) is pinned in
    # tests/test_state_snapshot_reuse.py
    if not bucketed.supports_prefix_reuse or bucketed.requires_state_restore:
        return
    plen = 16                            # capacity-window aligned
    long = _prompts(cfg, rng, (plen + 5,))[0]
    fr = _frames(cfg, rng, 1)
    cold, = bucketed.run([long], frames=fr)
    pkv = jnp.concatenate([cold.k[:, :plen], cold.v[:, :plen]], axis=-1)
    warm = bucketed.run_suffix(long[plen:], pkv,
                               frames=fr[0] if fr else None)
    assert warm.first_token == cold.first_token
    assert np.array_equal(np.asarray(warm.k), np.asarray(cold.k))
    assert warm.prompt_len == cold.prompt_len


def test_suffix_retraces_bounded_by_bucket_pairs():
    """Distinct prefix lengths inside one prefix bucket must share one
    compiled suffix program: the prefix KV is padded to the bucket and
    the real length is a traced operand, so retraces scale with
    (prefix bucket, suffix bucket) pairs only."""
    cfg, params = reduced_params("granite-3-8b")
    rng = np.random.default_rng(13)
    pe = PrefillEngine(cfg, params, bucket_prefill=True)
    long = _prompts(cfg, rng, (40,))[0]
    cold, = pe.run([long])
    cases = [(17, 5), (20, 9), (25, 3), (31, 6),        # prefix bucket 32
             (16, 5), (9, 4)]                           # prefix bucket 16
    pairs = {(pe._bucket_len(p), pe._bucket_len(s)) for p, s in cases}
    base = prefill_compile_count()
    firsts = {}
    for plen, slen in cases:
        pkv = jnp.concatenate([cold.k[:, :plen], cold.v[:, :plen]],
                              axis=-1)
        warm = pe.run_suffix(long[plen:plen + slen], pkv)
        firsts[(plen, slen)] = warm.first_token
    delta = prefill_compile_count() - base
    assert delta <= len(pairs) < len(cases)
    # and the warm outputs are right: spot-check against cold prefills
    for plen, slen in cases[:2]:
        want, = PrefillEngine(cfg, params,
                              bucket_prefill=False).run([long[:plen + slen]])
        assert firsts[(plen, slen)] == want.first_token, (plen, slen)


def test_capacity_moe_drops_are_pad_invariant():
    """Force real capacity overflow (tiny capacity_factor) and check a
    padded row still produces the exact-length outputs: the keep
    threshold comes from the VALID token count and pads take no slots."""
    cfg, params = reduced_params("qwen2-moe-a2.7b")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                              capacity_factor=0.25))
    from repro.models.modeling import forward_prefill
    rng = np.random.default_rng(2)
    ln = 11
    toks = rng.integers(0, cfg.vocab_size, ln)
    li = jnp.asarray([ln - 1])
    f_e, c_e = forward_prefill(cfg, params,
                               {"tokens": jnp.asarray(toks[None],
                                                      jnp.int32)},
                               last_index=li)
    pad = np.zeros(16, np.int64)
    pad[:ln] = toks
    f_p, c_p = forward_prefill(cfg, params,
                               {"tokens": jnp.asarray(pad[None],
                                                      jnp.int32)},
                               last_index=li)
    assert int(f_e[0]) == int(f_p[0])
    for sub, leaves in c_e["layers"].items():
        for name, a in leaves.items():
            b = np.asarray(c_p["layers"][sub][name])[:, :, :ln] \
                if name in ("k", "v") else np.asarray(c_p["layers"][sub][name])
            assert np.array_equal(np.asarray(a), b), (sub, name)


def test_capacity_moe_warm_prefix_matches_cold_serving():
    """The lifted prefix-index gate, end to end: capacity-dispatch MoE
    served warm (window-aligned prefix hits, suffix-only prefill) must
    be token-identical to cold serving."""
    cfg, params = reduced_params("qwen2-moe-a2.7b")
    assert cfg.moe.dispatch == "capacity"
    rng = np.random.default_rng(3)
    prefix = list(map(int, rng.integers(0, cfg.vocab_size,
                                        cfg.moe.capacity_window)))
    prompts = [prefix + list(map(int, rng.integers(0, cfg.vocab_size, 5)))
               for _ in range(3)]

    cold, _ = serve_sequential(cfg, params, prompts, prefix_cache=False)
    warm, fe = serve_sequential(cfg, params, prompts, prefix_cache=True)
    assert warm == cold
    g = fe.groups["default"]
    node = prefill_node(fe)
    assert node.prefix_cache and node.prefix_align \
        == cfg.moe.capacity_window
    assert node.pool.hits == len(prompts) - 1
    assert node.engine.reused_tokens == \
        cfg.moe.capacity_window * (len(prompts) - 1)
    # compile-stall telemetry rides on the group ledger
    ts = g.transfer_stats()
    assert ts["prefill_compile_count"] >= 1.0
    assert 0.0 <= ts["prefill_bucket_hit_rate"] <= 1.0
    assert ts["prefill_batches"] == float(node.engine.prefill_batches)
    # pad waste only exists on the bucketed default (an engine built
    # with bucket_prefill=False pads nothing)
    assert 0.0 <= ts["prefill_pad_waste"] < 1.0
    if node.engine.bucket_prefill:
        assert ts["prefill_pad_waste"] > 0.0


def test_flash_prefill_bucketed_prefix_and_query_mask():
    """Kernel-level contract: a right-padded prefix region (prefix_pad >
    q_offset) and padded query rows (q_valid) must reproduce the
    exact-length oracle on valid rows, with padded queries emitting
    exactly zero."""
    from repro.kernels.flash_prefill import flash_prefill_pallas
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.normal(size=(2, 128, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 256, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 256, 32)), jnp.float32)
    got = flash_prefill_pallas(q, k, v, q_tile=64, kv_tile=64,
                               interpret=True, q_offset=70,
                               prefix_pad=128, q_valid=100)
    want = ref.flash_prefill(q, k, v, q_offset=70, prefix_pad=128,
                             q_valid=100)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # valid rows equal an exact-length (no prefix padding) run
    ke = jnp.concatenate([k[:, :70], k[:, 128:]], axis=1)
    ve = jnp.concatenate([v[:, :70], v[:, 128:]], axis=1)
    exact = ref.flash_prefill(q, ke, ve, q_offset=70)
    np.testing.assert_allclose(np.asarray(got)[:, :100],
                               np.asarray(exact)[:, :100],
                               rtol=2e-5, atol=2e-5)
    assert np.all(np.asarray(got)[:, 100:] == 0.0)
    assert np.all(np.asarray(want)[:, 100:] == 0.0)


@settings(max_examples=8, deadline=None)
@given(lens=st.lists(st.integers(min_value=1, max_value=15),
                     min_size=1, max_size=4))
def test_padding_never_changes_outputs_or_compute(lens):
    """Property: for ANY ragged batch, bucketing changes neither the
    emitted tokens nor the exact compute_tokens ledger — padding exists
    only in padded_tokens."""
    cfg, params = reduced_params("granite-3-8b")
    rng = np.random.default_rng(sum(lens) + len(lens))
    prompts = _prompts(cfg, rng, lens)
    exact = PrefillEngine(cfg, params, bucket_prefill=False)
    bucketed = PrefillEngine(cfg, params, bucket_prefill=True)
    o_e = exact.run(prompts)
    o_b = bucketed.run(prompts)
    assert [o.first_token for o in o_e] == [o.first_token for o in o_b]
    assert exact.compute_tokens == bucketed.compute_tokens == sum(lens)
    assert exact.padded_tokens <= bucketed.padded_tokens
