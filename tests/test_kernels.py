"""Per-kernel shape/dtype sweeps asserting allclose against the ref.py
pure-jnp oracles (deliverable c), plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.kv_gather import kv_gather_pallas
from repro.kernels.kv_scatter import kv_scatter_pallas
from repro.kernels.paged_attention import paged_attention_pallas

SHAPES = [
    # (L, NB, BS, kvd)
    (1, 4, 8, 64),
    (3, 16, 16, 128),
    (6, 32, 16, 256),
    (2, 8, 4, 64),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _storage(L, NB, BS, kvd, dtype, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(L, NB, BS, 2 * kvd)), dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_kv_gather_matches_ref(shape, dtype):
    L, NB, BS, kvd = shape
    storage = _storage(L, NB, BS, kvd, dtype)
    rng = np.random.default_rng(1)
    idx = jnp.asarray(rng.permutation(NB)[: NB // 2], jnp.int32)
    got = kv_gather_pallas(storage, idx, interpret=True)
    want = ref.kv_gather(storage, idx)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_kv_scatter_matches_ref(shape, dtype):
    L, NB, BS, kvd = shape
    storage = _storage(L, NB, BS, kvd, dtype)
    rng = np.random.default_rng(2)
    n = max(1, NB // 3)
    idx = jnp.asarray(rng.permutation(NB)[:n], jnp.int32)
    buf = jnp.asarray(rng.normal(size=(L, n * BS, 2 * kvd)), dtype)
    got = kv_scatter_pallas(storage, buf, idx, interpret=True)
    want = ref.kv_scatter(storage, buf, idx)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("gqa", [1, 4])
def test_paged_attention_matches_ref(shape, dtype, gqa):
    L, NB, BS, kvd = shape
    hd = 32
    nkv = kvd // hd
    nq = nkv * gqa
    B, MAXB = 3, min(4, NB)
    rng = np.random.default_rng(3)
    pages = jnp.asarray(rng.normal(size=(NB, BS, 2 * kvd)), dtype)
    q = jnp.asarray(rng.normal(size=(B, nq, hd)), dtype)
    bt = np.full((B, MAXB), -1, np.int32)
    lens = np.zeros(B, np.int32)
    for b in range(B):
        nb = rng.integers(1, MAXB + 1)
        bt[b, :nb] = rng.permutation(NB)[:nb]
        lens[b] = rng.integers(1, nb * BS + 1)
    got = paged_attention_pallas(q, pages, jnp.asarray(bt),
                                 jnp.asarray(lens), interpret=True)
    want = ref.paged_attention(q, pages, jnp.asarray(bt), jnp.asarray(lens))
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_gather_scatter_roundtrip(data):
    """Property: scatter(gather(pool, idx), idx) is the identity, and
    blocks not in idx are untouched by scatter."""
    NB = data.draw(st.integers(4, 24))
    BS = data.draw(st.sampled_from([4, 8, 16]))
    L = data.draw(st.integers(1, 4))
    kvd = data.draw(st.sampled_from([32, 64]))
    n = data.draw(st.integers(1, NB))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    storage = jnp.asarray(rng.normal(size=(L, NB, BS, 2 * kvd)), jnp.float32)
    idx = jnp.asarray(rng.permutation(NB)[:n], jnp.int32)
    buf = kv_gather_pallas(storage, idx, interpret=True)
    back = kv_scatter_pallas(storage, buf, idx, interpret=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(storage))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31), n_seq=st.integers(1, 5))
def test_paged_attention_is_permutation_invariant(seed, n_seq):
    """Property: physical block placement must not change the output —
    attention over pages depends only on the logical token order."""
    rng = np.random.default_rng(seed)
    NB, BS, kvd, hd = 16, 8, 64, 32
    nkv = kvd // hd
    q = jnp.asarray(rng.normal(size=(n_seq, nkv * 2, hd)), jnp.float32)
    tokens = [rng.normal(size=(rng.integers(1, 3) * BS, 2 * kvd))
              for _ in range(n_seq)]
    lens_fixed = np.asarray(
        [rng.integers(1, len(t) + 1) for t in tokens], np.int32)

    def build(order_seed):
        prm = np.random.default_rng(order_seed).permutation(NB)
        pages = np.zeros((NB, BS, 2 * kvd))
        bt = np.full((n_seq, 4), -1, np.int32)
        cursor = 0
        for i, t in enumerate(tokens):
            nb = len(t) // BS
            blocks = prm[cursor: cursor + nb]
            cursor += nb
            for j, b in enumerate(blocks):
                pages[b] = t[j * BS:(j + 1) * BS]
            bt[i, :nb] = blocks
        return (jnp.asarray(pages, jnp.float32), jnp.asarray(bt),
                jnp.asarray(lens_fixed))

    p1, b1, l1 = build(1)
    p2, b2, l2 = build(2)
    o1 = paged_attention_pallas(q, p1, b1, l1, interpret=True)
    o2 = paged_attention_pallas(q, p2, b2, l2, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------- flash prefill
from repro.kernels.flash_prefill import flash_prefill_pallas


@pytest.mark.parametrize("s,hd", [(128, 64), (256, 64), (256, 128)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_flash_prefill_matches_ref(s, hd, dtype):
    rng = np.random.default_rng(7)
    bh = 3
    q = jnp.asarray(rng.normal(size=(bh, s, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(bh, s, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(bh, s, hd)), dtype)
    got = flash_prefill_pallas(q, k, v, q_tile=128, kv_tile=128,
                               interpret=True)
    want = ref.flash_prefill(q, k, v)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_prefill_causality():
    """Property: output at position i must not depend on tokens > i."""
    rng = np.random.default_rng(8)
    s, hd = 128, 64
    q = jnp.asarray(rng.normal(size=(1, s, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, s, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, s, hd)), jnp.float32)
    o1 = flash_prefill_pallas(q, k, v, interpret=True)
    k2 = k.at[0, 100:].set(99.0)   # perturb the future
    v2 = v.at[0, 100:].set(-99.0)
    o2 = flash_prefill_pallas(q, k2, v2, interpret=True)
    np.testing.assert_allclose(np.asarray(o1[0, :100]),
                               np.asarray(o2[0, :100]), rtol=1e-6)
