"""SLO-goodput autoscaler over a shared heterogeneous pool (ISSUE 10).

Covers the control plane end to end on the REAL serving path: the
GoodputModel capacity law, NodePool lease/release/adopt accounting (a
crashed node is never double-counted as pool capacity), scale-up /
scale-down as events on the tickless heap, the RatioAdjuster standing
down while a scale op is in flight, and chaos composition — a crash
during a scale event stays deterministic (same seed, bit-identical
MetaStore audit log) and every request served by the autoscaled run is
token-identical to a fault-free static run. ``CHAOS_SEED`` (CI matrix)
perturbs fault times without weakening any assertion.
"""
import os

import numpy as np
import pytest

from conftest import reduced_params
from repro.core.mlops import GoodputModel, SLOSpec, substitute_ready_delay
from repro.core.profiles import NODE_CLASSES
from repro.serving.autoscale import AutoScaler, NodePool
from repro.serving.cluster import ServeRequest
from repro.serving.faults import (DeterministicService, FaultEvent,
                                  FaultPlan)
from repro.serving.frontend import ClusterFrontend

SEED = int(os.environ.get("CHAOS_SEED", "0"))
# slow prefill -> the burst below is TTFT-bound at ~50 req/s per node,
# so a 500 req/s burst forces the scaler's hand
SVC = DeterministicService(prefill_base_s=0.02, prefill_per_token_s=5e-4)
SLO = SLOSpec(ttft_s=0.06, tpot_s=0.01)


def _reqs(cfg, n, *, seed=3, max_new=4, rid0=0, deadline=4.0):
    rng = np.random.default_rng(seed)
    return [ServeRequest(
        rid=rid0 + i,
        tokens=list(map(int, rng.integers(0, cfg.vocab_size,
                                          int(rng.integers(5, 12))))),
        max_new_tokens=max_new, slo_deadline_s=deadline)
        for i in range(n)]


def _frontend(cfg, params, **kw):
    kw.setdefault("topology", {"default": (1, 1)})
    kw.setdefault("prefill_kwargs", {"batch_size": 2})
    return ClusterFrontend(cfg, params=params, service_model=SVC,
                           absorb_prefill=True, **kw)


def _scaler(fe, inventory, **kw):
    pool = NodePool(inventory, provision_scale=0.002)
    kw.setdefault("period_s", 0.05)
    kw.setdefault("window_s", 0.5)
    kw.setdefault("cooldown_s", 0.1)
    return pool, AutoScaler(fe, pool, SLO, **kw)


def _burst(fe, cfg, *, n=60, trickle=10, max_new=4):
    rs = _reqs(cfg, n, max_new=max_new)
    for i, r in enumerate(rs):
        fe.submit(r, at=0.002 * i)            # 500 req/s for n*2 ms
    tail = _reqs(cfg, trickle, rid0=1000, seed=9, max_new=max_new)
    for i, r in enumerate(tail):
        fe.submit(r, at=1.0 + 0.2 * i)        # idle-ish tail: shrink
    return rs + tail


def _assert_clean(g):
    for node in g.prefills + g.decodes:
        assert node.pool.invariant_ok(), node.iid


# --------------------------------------------------- GoodputModel law

def test_goodput_model_gates_on_samples():
    assert GoodputModel.from_stats(SLO, {}) is None
    assert GoodputModel.from_stats(
        SLO, {"prefill_batch_median_s": 0.01}) is None
    m = GoodputModel.from_stats(SLO, {"prefill_batch_median_s": 0.01,
                                      "decode_step_median_s": 0.002},
                                batch_size=2, decode_slots=8,
                                gen_tokens=4.0)
    assert m is not None


def test_goodput_model_capacities():
    m = GoodputModel.from_stats(SLO, {"prefill_batch_median_s": 0.02,
                                      "decode_step_median_s": 0.002},
                                batch_size=2, decode_slots=8,
                                gen_tokens=4.0)
    # headroom = 1 - 0.02/0.06
    assert m.prefill_headroom() == pytest.approx(2.0 / 3.0)
    # 1 node: 2 req / 0.02 s, derated by headroom
    assert m.prefill_capacity(1.0) == pytest.approx(100.0 * 2.0 / 3.0)
    assert m.prefill_capacity(2.0) == pytest.approx(2 * 100.0 * 2.0 / 3.0)
    # 8 slots emitting every 2 ms, 4 tokens per request
    assert m.decode_capacity(1.0) == pytest.approx(8 / (4.0 * 0.002))
    # goodput is min(rate, caps)
    assert m.goodput(50.0, 1.0, 1.0) == pytest.approx(50.0)
    assert m.goodput(5000.0, 1.0, 1.0) == pytest.approx(
        min(m.prefill_capacity(1.0), m.decode_capacity(1.0)))


def test_goodput_model_infeasible_tpot():
    # a decode step slower than the TPOT SLO can never meet it
    m = GoodputModel.from_stats(SLO, {"prefill_batch_median_s": 0.02,
                                      "decode_step_median_s": 0.02})
    assert m.decode_capacity(100.0) == 0.0
    assert m.nodes_needed(1.0)[1] >= 1 << 20


# ------------------------------------------------- NodePool accounting

def test_pool_lease_prefers_role_bias():
    pool = NodePool({"balanced": 1, "prefill-heavy": 1,
                     "decode-heavy": 1})
    assert pool.lease("P", "a").name == "prefill-heavy"
    assert pool.lease("P", "b").name == "balanced"   # bias exhausted
    assert pool.lease("P", "c").name == "decode-heavy"
    assert pool.lease("P", "d") is None
    assert pool.n_denied == 1


def test_pool_release_is_idempotent():
    """The crashed-node guard: releasing an iid that was already
    released (or never leased) is a no-op — capacity cannot be
    double-counted back into the pool."""
    pool = NodePool({"balanced": 1})
    assert pool.lease("D", "x") is not None
    assert pool.total_free() == 0
    assert pool.release("x") is True
    assert pool.total_free() == 1
    assert pool.release("x") is False          # second release: no-op
    assert pool.release("never-leased") is False
    assert pool.total_free() == 1
    assert pool.ledger()["pool_releases_total"] == 1.0


def test_pool_adopt_and_provision_delay():
    pool = NodePool({}, provision_scale=0.5)
    pool.adopt("decode-heavy")
    assert pool.free["decode-heavy"] == 1
    pool.adopt("unknown-class")                # falls back to balanced
    assert pool.free["balanced"] == 1
    ncls = NODE_CLASSES["balanced"]
    assert pool.provision_delay(ncls) == pytest.approx(
        0.5 * substitute_ready_delay(ncls.provision_level, storage="ssd"))


# --------------------------------------------------- scale up / down

def test_burst_scales_up_then_trickle_scales_down():
    cfg, params = reduced_params("granite-3-8b")
    fe = _frontend(cfg, params)
    pool, sc = _scaler(fe, {"prefill-heavy": 2, "decode-heavy": 2})
    rs = _burst(fe, cfg)
    fe.serve(watch=rs, max_events=500_000)
    g = fe.groups["default"]
    assert all(r.done for r in rs)
    assert not any(r.shed for r in rs)
    st = g.transfer_stats()
    assert st["scale_up_done"] >= 1            # burst forced a lease
    assert st["scale_down_done"] >= 1          # trickle drained it back
    assert st["scale_up_done"] == st["scale_up_started"]
    assert st["scale_down_done"] == st["scale_down_started"]
    # every lease returned: pool conserves nodes
    led = pool.ledger()
    assert led["pool_leased"] == 0.0
    assert led["pool_free"] == 4.0
    assert led["pool_leases_total"] == led["pool_releases_total"]
    # scaled-up nodes drained out of the group again
    assert [n.iid for n in g.prefills] == ["g0/P0"]
    assert [n.iid for n in g.decodes] == ["g0/D0"]
    # up ops leased the role-biased class
    ups = [o for o in sc.ops if o.kind == "up"]
    assert ups and all(o.ncls == "prefill-heavy" for o in ups
                       if o.role == "P")
    _assert_clean(g)


def test_exhausted_pool_degrades_gracefully():
    """No spares at all: scale-up is denied, and the burst is carried by
    chunked-prefill absorption + gateway backoff instead of failing.
    The burst is prefill-complete (max_new=0 scoring traffic) so the
    decode node is genuinely idle — the only regime absorb may run in:
    a chunk's wall dwarfs the TPOT budget of co-resident decodes."""
    cfg, params = reduced_params("granite-3-8b")
    fe = _frontend(cfg, params)
    pool, sc = _scaler(fe, {})
    # a few decoded requests first: the goodput model gates until the
    # group has measured at least one decode step
    warm = _reqs(cfg, 3, rid0=500, seed=11, max_new=2)
    for i, r in enumerate(warm):
        fe.submit(r, at=0.002 * i)
    rs = _burst(fe, cfg, max_new=0)
    fe.serve(watch=rs + warm, max_events=500_000)
    g = fe.groups["default"]
    assert all(r.done for r in rs)
    assert pool.n_denied >= 1
    assert g.transfer_stats()["scale_denied"] >= 1
    assert g.absorbs["absorb_requests"] >= 1   # decode node helped
    _assert_clean(g)


def test_transfer_stats_exposes_scale_ledger():
    cfg, params = reduced_params("granite-3-8b")
    fe = _frontend(cfg, params)
    _scaler(fe, {"balanced": 1})
    st = fe.groups["default"].transfer_stats()
    for key in ("scale_up_started", "scale_up_done", "scale_down_started",
                "scale_down_done", "scale_denied", "scale_in_flight"):
        assert key in st


# ------------------------------------------- adjuster x scaler interplay

def test_adjuster_stands_down_during_scale_op():
    cfg, params = reduced_params("granite-3-8b")
    fe = _frontend(cfg, params, topology={"default": (2, 2)},
                   adjust_ratio=True)
    adj = fe.adjusters["default"]
    g = fe.groups["default"]
    adj._last_want = "P->D"                    # half-confirmed flip
    g.scale_op = object()                      # scale in flight
    assert adj.maybe_adjust(adj.interval, backlog=50) is None
    assert adj._last_want is None              # hysteresis reset too
    g.scale_op = None                          # resume after


def test_adjuster_resumes_after_scale_completes():
    """With the op cleared the adjuster is live again: the same pressure
    that was ignored mid-scale can flip a node on the next beat."""
    cfg, params = reduced_params("granite-3-8b")
    fe = _frontend(cfg, params, topology={"default": (2, 2)},
                   adjust_ratio=True)
    rs = _reqs(cfg, 12)
    for i, r in enumerate(rs):
        fe.submit(r, at=0.001 * i)
    fe.serve(watch=rs, max_events=200_000)
    assert all(r.done for r in rs)             # no deadlock either way
    _assert_clean(fe.groups["default"])


# --------------------------------------------------- chaos composition

def _chaos_run(cfg, params, plan, inventory):
    fe = _frontend(cfg, params, topology={"default": (1, 2)},
                   prefill_kwargs={"batch_size": 1},
                   faults=plan, health_timeout_s=0.05,
                   fault_kwargs={"heartbeat_s": 0.02,
                                 "recover_delay_s": 0.05})
    pool, sc = _scaler(fe, inventory)
    rs = _burst(fe, cfg, n=40, trickle=6)
    fe.serve(watch=rs, max_events=500_000)
    return fe, pool, sc, rs


def test_crash_during_scale_deterministic_and_token_identical():
    cfg, params = reduced_params("granite-3-8b")
    # fault-free static reference at generous capacity
    ref = _frontend(cfg, params, topology={"default": (2, 3)},
                    prefill_kwargs={"batch_size": 1})
    ref_rs = _burst(ref, cfg, n=40, trickle=6)
    ref.serve(watch=ref_rs, max_events=500_000)
    golden = {r.rid: tuple(r.generated) for r in ref_rs}

    # crash a decode node mid-burst, while the scaler is provisioning
    rng = np.random.default_rng(1000 + SEED)
    t_crash = float(rng.uniform(0.02, 0.08))
    plan = FaultPlan([FaultEvent(t_crash, "crash", "g0/D0", 0.05)])
    sigs = []
    for _ in range(2):
        fe, pool, sc, rs = _chaos_run(cfg, params, plan,
                                      {"prefill-heavy": 1, "balanced": 1})
        g = fe.groups["default"]
        assert all(r.done or r.shed for r in rs)
        for r in rs:
            if r.done and not r.shed:
                assert tuple(r.generated) == golden[r.rid], r.rid
        st = g.transfer_stats()
        # ft and scale ledgers stay mutually consistent
        assert st.get("ft_crashes", 0) >= 1
        assert st["scale_up_started"] >= st["scale_up_done"]
        assert st["scale_down_started"] >= st["scale_down_done"]
        led = pool.ledger()
        assert led["pool_leases_total"] >= led["pool_releases_total"]
        # whatever is not released is still genuinely leased out
        assert led["pool_leased"] == (led["pool_leases_total"]
                                      - led["pool_releases_total"])
        # conservation: inventory only grows by adopted base nodes
        assert led["pool_free"] + led["pool_leased"] == \
            2.0 + led["pool_adopted"]
        _assert_clean(g)
        sigs.append((tuple(fe.meta.events), fe.meta.n_events,
                     tuple(sorted((r.rid, tuple(r.generated))
                                  for r in rs))))
    # same seed -> bit-identical audit log and token streams
    assert sigs[0] == sigs[1]


def test_crashed_scaled_node_not_double_counted():
    """Crash the node the scaler leased: its lease must stay held (or
    release exactly once on decommission) — pool free+leased is conserved
    at the inventory size through crash, reboot, drain, decommission."""
    cfg, params = reduced_params("granite-3-8b")
    plan = FaultPlan([FaultEvent(0.3, "crash", "g0/S0", 0.05)])
    fe, pool, sc, rs = _chaos_run(cfg, params, plan, {"prefill-heavy": 2})
    assert all(r.done or r.shed for r in rs)
    led = pool.ledger()
    assert led["pool_free"] + led["pool_leased"] == \
        2.0 + led["pool_adopted"]
    assert led["pool_releases_total"] <= led["pool_leases_total"]
    _assert_clean(fe.groups["default"])
