"""Deterministic fault injection + token-exact crash recovery on the
real event-driven data path (serving/faults.py).

The contract under test: crash-killing any single prefill or decode
node at an arbitrary FaultPlan time yields TOKEN-IDENTICAL output
streams for every completed request vs the fault-free run (greedy
decode; decode recovery re-prefills prompt + tokens emitted so far),
leaks no pool blocks, and the same FaultPlan seed produces bit-identical
event logs across runs. ``CHAOS_SEED`` (CI matrix) perturbs fault times
without weakening any assertion.
"""
import dataclasses
import os

import numpy as np
import pytest

from conftest import reduced_params
from repro.serving.cluster import ServeRequest
from repro.serving.faults import (DeterministicService, FaultEvent,
                                  FaultPlan)
from repro.serving.frontend import ClusterFrontend

# dense / MoE / attn-free SSM / hybrid — every KV-payload shape the
# transfer+recovery path must survive
CHAOS_FAMILIES = ["granite-3-8b", "qwen2-moe-a2.7b", "mamba2-2.7b",
                  "jamba-1.5-large-398b"]
SEED = int(os.environ.get("CHAOS_SEED", "0"))
SVC = DeterministicService()


def _cfg_params(arch):
    cfg, params = reduced_params(arch)
    if cfg.moe is not None:
        # capacity dispatch drops tokens batch-dependently; parity tests
        # pin the lossless sorted path (same idiom as the event-loop
        # and transfer parity suites)
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  dispatch="sorted"))
    return cfg, params


def _requests(cfg, n, *, max_new=6, seed=3):
    rng = np.random.default_rng(seed)
    return [ServeRequest(
        rid=i,
        tokens=list(map(int, rng.integers(0, cfg.vocab_size,
                                          int(rng.integers(5, 12))))),
        max_new_tokens=max_new) for i in range(n)]


def _frontend(cfg, params, plan=None, *, topo=(1, 2), recover_s=0.05,
              heartbeat_s=0.02, timeout_s=0.05):
    # batch_size=1: singleton prefill batches are trivially identical
    # between the baseline and chaos runs (batch-composition invariance
    # is pinned elsewhere; chaos parity must not depend on it)
    return ClusterFrontend(
        cfg, topology={"default": topo}, params=params,
        prefill_kwargs={"batch_size": 1}, service_model=SVC,
        faults=plan, health_timeout_s=timeout_s,
        fault_kwargs={"heartbeat_s": heartbeat_s,
                      "recover_delay_s": recover_s})


def _run(cfg, params, reqs, plan=None, **kw):
    fe = _frontend(cfg, params, plan, **kw)
    for i, r in enumerate(reqs):
        fe.submit(r, at=0.002 * i)
    fe.serve(watch=reqs, max_events=200_000)
    return fe


def _assert_clean(group):
    for node in group.prefills + group.decodes:
        assert node.pool.invariant_ok(), node.iid


# ------------------------------------------------- token identity matrix

@pytest.mark.parametrize("arch", CHAOS_FAMILIES)
def test_decode_crash_token_identity(arch):
    """Crash-kill a decode node mid-stream: every in-flight request is
    re-admitted elsewhere by re-prefilling prompt + emitted tokens, and
    the final streams equal the fault-free run token for token."""
    cfg, params = _cfg_params(arch)
    base = _requests(cfg, 2)
    _run(cfg, params, base)
    assert all(r.done for r in base)

    t_crash = 0.015 + SEED * 1e-4
    plan = FaultPlan([FaultEvent(t_crash, "crash", "g0/D0", 0.05)])
    chaos = _requests(cfg, 2)
    fe = _run(cfg, params, chaos, plan)
    g = fe.groups["default"]
    assert g.ft.n_crashes == 1
    for a, b in zip(base, chaos):
        assert b.done and not b.shed
        assert b.generated == a.generated
    _assert_clean(g)


@pytest.mark.parametrize("arch", CHAOS_FAMILIES)
def test_prefill_crash_token_identity(arch):
    """Crash-kill a prefill node: forming requests requeue to healthy
    peers, in-flight transfers it sourced die (fail_src) and their
    requests re-admit — token-identical to the fault-free run."""
    cfg, params = _cfg_params(arch)
    base = _requests(cfg, 3)
    _run(cfg, params, base, topo=(2, 1))
    assert all(r.done for r in base)

    t_crash = 0.0045 + SEED * 1e-4
    plan = FaultPlan([FaultEvent(t_crash, "crash", "g0/P0", 0.05)])
    chaos = _requests(cfg, 3)
    fe = _run(cfg, params, chaos, plan, topo=(2, 1))
    g = fe.groups["default"]
    assert g.ft.n_crashes == 1
    for a, b in zip(base, chaos):
        assert b.done and not b.shed
        assert b.generated == a.generated
    _assert_clean(g)


def test_decode_crash_readmits_and_ledger():
    """The granite decode-crash run actually exercises re-admission (not
    a lucky quiet window), and the recovery ledger shows up in
    transfer_stats()."""
    cfg, params = _cfg_params("granite-3-8b")
    plan = FaultPlan([FaultEvent(0.015, "crash", "g0/D0", 0.05)])
    reqs = _requests(cfg, 3)
    fe = _run(cfg, params, reqs, plan)
    g = fe.groups["default"]
    assert g.ft.n_readmitted >= 1
    assert all(r.done for r in reqs)
    assert any(r.readmits > 0 for r in reqs)
    stats = fe.transfer_stats()["default"]
    for key in ("ft_crashes", "ft_ejections", "ft_restores",
                "ft_requests_requeued", "ft_requests_readmitted",
                "ft_requests_shed", "ft_recovery_wall_median_s",
                "ft_health_epoch_lag_median_s",
                "ft_readmit_prefix_hit_rate"):
        assert key in stats, key
    assert stats["ft_crashes"] == 1.0
    _assert_clean(g)


def test_prefill_crash_kills_sourced_transfers():
    """fail_src path: the dead prefill's in-flight transfer jobs are
    dropped (their linearized buffers died with the node) and the
    affected requests re-enter through a healthy peer."""
    cfg, params = _cfg_params("granite-3-8b")
    plan = FaultPlan([FaultEvent(0.0045, "crash", "g0/P0", 0.05)])
    reqs = _requests(cfg, 4)
    fe = _run(cfg, params, reqs, plan, topo=(2, 1))
    g = fe.groups["default"]
    assert g.sched.n_src_failed >= 1
    assert g.ft.n_readmitted + g.ft.n_requeued >= 1
    assert all(r.done for r in reqs)
    _assert_clean(g)


# ------------------------------------------------------- reproducibility

def test_same_seed_bit_identical_event_log():
    """Same FaultPlan seed => bit-identical group event log, chaos
    action log, and token streams across runs (the DeterministicService
    model replaces measured wall times on the virtual clock)."""
    cfg, params = _cfg_params("granite-3-8b")

    def chaos_run():
        plan = FaultPlan.random(
            7 + SEED, nodes=["g0/P0", "g0/D0", "g0/D1"],
            t_lo=0.005, t_hi=0.05, n_events=3,
            kinds=("crash", "hang"), hang_s=0.1, crash_recover_s=0.05)
        reqs = _requests(cfg, 3)
        fe = _frontend(cfg, params, plan)
        for i, r in enumerate(reqs):
            fe.submit(r, at=0.002 * i)
        fe.serve(max_events=200_000)   # drain recovery events too
        g = fe.groups["default"]
        return list(g.event_log), list(g.ft.log), \
            [list(r.generated) for r in reqs]

    ev1, log1, toks1 = chaos_run()
    ev2, log2, toks2 = chaos_run()
    assert ev1 == ev2
    assert log1 == log2
    assert toks1 == toks2
    assert any(kind in ("crash", "hang") for _, kind, _ in log1)


def test_fault_plan_seeded_and_sorted():
    p1 = FaultPlan.random(11, nodes=["a", "b"], links=[("a", "b")],
                          t_lo=0.0, t_hi=1.0, n_events=5)
    p2 = FaultPlan.random(11, nodes=["a", "b"], links=[("a", "b")],
                          t_lo=0.0, t_hi=1.0, n_events=5)
    assert p1.events == p2.events
    assert list(p1) == sorted(p1, key=lambda e: (e.t, e.kind, e.target))
    p3 = FaultPlan.random(12, nodes=["a", "b"], links=[("a", "b")],
                          t_lo=0.0, t_hi=1.0, n_events=5)
    assert p3.events != p1.events


# --------------------------------------------- health epochs & ejection

def test_silent_node_ejected_at_exact_deadline():
    """Satellite: per-store health timeout on the virtual clock. A node
    that hangs is ejected at EXACTLY last_report + health_timeout_s —
    the controller schedules a precisely-timestamped eject event instead
    of discovering the timeout at the next (laggy) epoch."""
    cfg, params = _cfg_params("granite-3-8b")
    hb, timeout = 0.02, 0.05
    plan = FaultPlan([FaultEvent(0.03, "hang", "g0/D0", 0.2)])
    fe = _frontend(cfg, params, plan, heartbeat_s=hb, timeout_s=timeout)
    assert fe.meta.health_timeout_s == timeout
    fe.serve(max_events=200_000)
    ft = fe.groups["default"].ft
    ejects = [e for e in ft.log if e[1] == "eject"]
    assert len(ejects) == 1
    # last heartbeat report before the hang lands at t=hb; the eject
    # must fire at last_report + timeout, not at an epoch boundary
    assert ejects[0][0] == pytest.approx(hb + timeout, abs=1e-9)
    # the straggler resumes at 0.23 and rejoins with its memory intact
    assert ft.n_restored == 1
    assert ft.recovery_walls and ft.recovery_walls[0] == \
        pytest.approx(0.23 - (hb + timeout), abs=1e-9)


def test_short_hang_straggles_without_ejection():
    """A hang shorter than the health timeout just delays the node
    (busy_until rides the virtual clock); nothing is ejected and the
    streams still complete identically."""
    cfg, params = _cfg_params("granite-3-8b")
    base = _requests(cfg, 2)
    _run(cfg, params, base)
    plan = FaultPlan([FaultEvent(0.01, "hang", "g0/D0", 0.03)])
    chaos = _requests(cfg, 2)
    fe = _run(cfg, params, chaos, plan, timeout_s=0.5)
    ft = fe.groups["default"].ft
    assert ft.n_hangs == 1 and ft.n_ejected == 0
    for a, b in zip(base, chaos):
        assert b.done and b.generated == a.generated


# ------------------------------------------------ substitute integration

def test_failed_node_restored_takes_transfers_again():
    """Satellite regression: TransferScheduler.failed_nodes was a
    one-way set. Crash the SOLE decode node before traffic arrives; the
    rebooted substitute must be removed from failed_nodes
    (restore_node) and land transfers, or the requests starve."""
    cfg, params = _cfg_params("granite-3-8b")
    plan = FaultPlan([FaultEvent(0.0005, "crash", "g0/D0", 0.01)])
    reqs = _requests(cfg, 2)
    fe = _frontend(cfg, params, plan, topo=(1, 1))
    for i, r in enumerate(reqs):
        fe.submit(r, at=0.02 + 0.002 * i)
    fe.serve(watch=reqs, max_events=200_000)
    g = fe.groups["default"]
    assert all(r.done for r in reqs)
    assert g.sched.n_restores == 1
    assert not g.sched.failed_nodes
    assert g.ft.n_restored == 1
    assert g.ft.recovery_walls
    # the substitute re-registered in the meta store
    assert "g0/D0" in fe.meta.group_members("g0", "D")
    _assert_clean(g)


def test_slo_hopeless_request_is_shed():
    """Recovery does not burn compute on a request whose SLO deadline
    already passed: it is shed (done, flagged) and ledgered."""
    cfg, params = _cfg_params("granite-3-8b")
    plan = FaultPlan([FaultEvent(0.012, "crash", "g0/D0", 0.5)])
    req = _requests(cfg, 1, max_new=8)[0]
    req.slo_deadline_s = 0.008
    fe = _run(cfg, params, [req], plan, topo=(1, 1), recover_s=0.5)
    ft = fe.groups["default"].ft
    assert req.shed and req.done
    assert ft.n_shed == 1
    assert fe.transfer_stats()["default"]["ft_requests_shed"] == 1.0


# -------------------------------------------------------------- guards

def test_faults_require_tickless():
    """The staged tick() shim pops queued events regardless of time, so
    future-dated fault events would fire early — rejected up front."""
    cfg, params = _cfg_params("granite-3-8b")
    plan = FaultPlan([FaultEvent(0.5, "crash", "g0/D0")])
    with pytest.raises(ValueError, match="tickless"):
        ClusterFrontend(cfg, topology={"default": (1, 1)}, params=params,
                        faults=plan, tickless=False)


def test_metastore_timeout_threaded():
    """Satellite: MetaStore.unhealthy's hard-coded 60 s timeout is now
    per-store config; the per-call override still wins."""
    from repro.core.zookeeper import MetaStore
    ms = MetaStore(health_timeout_s=0.1)
    ms.gather_instance(0.0, "n0", "P", "g0")
    ms.health_report(0.0, "n0")
    assert ms.unhealthy(0.05) == []
    assert ms.unhealthy(0.2) == ["n0"]          # per-store default
    assert ms.unhealthy(0.2, timeout=1.0) == []  # per-call override
    assert ms.silent_since("n0") == 0.0
    assert ms.silent_since("ghost") is None
