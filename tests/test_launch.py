"""Launcher + distribution-spec coverage: CLI smoke runs and in-process
lowering of the step functions against a (1-device) mesh via input_specs —
the same code path the 512-device dry-run exercises."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from conftest import reduced_params
from repro.launch.mesh import make_test_mesh
from repro.launch.specs import input_specs
from repro.models.config import ShapeConfig
from repro.models.steps import (decode_window, make_prefill_step,
                                make_serve_step, make_train_step)


@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_input_specs_lower_on_mesh(kind):
    cfg, _ = reduced_params("granite-3-8b")
    mesh = make_test_mesh()
    shape = ShapeConfig("t", 64, 4, kind)
    args, shardings = input_specs(cfg, shape, mesh)
    if kind == "train":
        step = make_train_step(cfg, mesh=mesh)
        donate = (0, 1)
    elif kind == "prefill":
        step = make_prefill_step(cfg, mesh=mesh)
        donate = ()
    else:
        step = make_serve_step(cfg, window=decode_window(cfg, shape),
                               mesh=mesh)
        donate = (1,)
    with mesh:
        compiled = jax.jit(step, in_shardings=shardings,
                           donate_argnums=donate).lower(*args).compile()
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes >= 0


def test_microbatched_train_step_matches_plain():
    """Gradient accumulation must give the same loss metric and close
    parameter updates as the monolithic step."""
    import numpy as np
    from repro.data import SyntheticLM
    from repro.training.optimizer import adamw_init
    cfg, params = reduced_params("minicpm-2b")
    data = SyntheticLM(cfg.vocab_size, 32, 8, seed=2)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    opt = adamw_init(params)
    p1, _, m1 = jax.jit(make_train_step(cfg))(params, opt, batch)
    p2, _, m2 = jax.jit(make_train_step(cfg, microbatches=4))(
        params, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)


def _run(mod, *args):
    return subprocess.run(
        [sys.executable, "-m", mod, *args],
        capture_output=True, text=True, timeout=500,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd=".").returncode


def test_train_cli_smoke():
    rc = _run("repro.launch.train", "--arch", "minicpm-2b", "--reduced",
              "--steps", "25", "--batch", "4", "--seq", "64",
              "--lr", "3e-3")
    assert rc == 0


def test_serve_cli_smoke():
    rc = _run("repro.launch.serve", "--arch", "mamba2-2.7b",
              "--requests", "4", "--max-new-tokens", "3")
    assert rc == 0
