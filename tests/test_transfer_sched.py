"""Overlapped layer-wise KV transfer pipeline (paper §3.6, Fig. 10) on
the real data path.

The overlapped (per-layer-triggered, event-driven admission) path must
be token-identical to the blocking synchronous path across families —
including warm prefix-reuse requests — and a mid-transfer failover must
requeue the request to another decode node with bit-exact KV delivery.
"""
import dataclasses
from types import SimpleNamespace

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_params
from repro.core.transfer import LinkModel
from repro.serving.cluster import MiniCluster, ServeRequest
from repro.serving.kvcache import PagedKVPool
from repro.serving.transfer_sched import TransferScheduler

POOL_KW = {"block_size": 4, "num_blocks": 96}

# one config per family: dense / MoE (dropless sorted, the
# prefix-transparent dispatch) / hybrid SSM+attn / encoder-decoder
FAMILIES = ["granite-3-8b", "qwen2-moe-a2.7b", "jamba-1.5-large-398b",
            "whisper-base"]


def _family_setup(arch, rng):
    cfg, params = reduced_params(arch)
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  dispatch="sorted"))
    frames = None
    if cfg.is_encoder_decoder:
        frames = np.asarray(
            rng.normal(size=(cfg.encoder_seq, cfg.d_model)) * 0.1,
            np.float32)
    return cfg, params, frames


def _serve(cfg, params, prompts, *, overlap, frames=None, max_new=3):
    mc = MiniCluster(cfg, n_prefill=1, n_decode=2, params=params,
                     overlap_transfer=overlap)
    gens = []
    for i, toks in enumerate(prompts):
        req = ServeRequest(rid=i, tokens=list(toks), max_new_tokens=max_new,
                           frames=frames)
        mc.run([req], max_ticks=80)
        assert req.done, (i, overlap)
        gens.append(list(req.generated))
    return gens, mc


@pytest.mark.parametrize("arch", FAMILIES)
def test_overlapped_matches_blocking(arch):
    """Token parity of the pipelined path vs the synchronous path. The
    repeated first prompt exercises the warm prefix-reuse suffix-only
    prefill through the pipeline on reuse-capable archs (hybrid archs
    take their skip path and must still match)."""
    rng = np.random.default_rng(11)
    cfg, params, frames = _family_setup(arch, rng)
    base = list(map(int, rng.integers(0, cfg.vocab_size, 11)))
    prompts = [base,
               list(map(int, rng.integers(0, cfg.vocab_size, 7))),
               base + list(map(int, rng.integers(0, cfg.vocab_size, 4)))]
    blocking, _ = _serve(cfg, params, prompts, overlap=False,
                         frames=frames)
    overlapped, mc = _serve(cfg, params, prompts, overlap=True,
                            frames=frames)
    assert overlapped == blocking
    g = mc.frontend.groups["default"]
    tf = g.transfer_stats()
    assert tf["overlapped"] == 1.0
    assert tf["jobs_admitted"] == len(prompts)
    assert tf["requeues"] == 0.0
    # per-link single-message invariant held on the real run
    for link in g.sched.links.values():
        hist = sorted(link.history)
        assert all(a[1] <= b[0] + 1e-12 for a, b in zip(hist, hist[1:]))


def _fake_job_inputs(cfg, rng, tokens, rid):
    L = sum(1 for k in cfg.layer_kinds() if k == "attn")
    k = jnp.asarray(rng.normal(size=(L, tokens, cfg.kv_dim)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(L, tokens, cfg.kv_dim)), jnp.float32)
    out = SimpleNamespace(k=k, v=v, prompt_len=tokens, mamba_state={},
                          cross=None, first_token=1)
    req = SimpleNamespace(rid=rid, max_new_tokens=4)
    return req, out, k, v


def test_failover_requeue_delivers_bit_exact_kv():
    """Kill the target decode node mid-transfer: the scheduler must
    release the partially-written dst blocks (no leak) and re-send every
    segment to the fallback node, byte-identical to a direct copy."""
    cfg, _ = reduced_params("granite-3-8b")
    rng = np.random.default_rng(4)
    d0 = SimpleNamespace(iid="D0", pool=PagedKVPool(cfg, **POOL_KW),
                         draining=False)
    d1 = SimpleNamespace(iid="D1", pool=PagedKVPool(cfg, **POOL_KW),
                         draining=False)
    sched = TransferScheduler(
        LinkModel(), pick_dst=lambda job: d1 if job.dst is d0 else d0)
    req, out, k, v = _fake_job_inputs(cfg, rng, tokens=13, rid=3)
    job = sched.begin(req, out, src_iid="P0", dst=d0, compute_s=0.0)
    assert sched.pending_for("D0") == 1
    # pump just past the FIRST layer segment's completion: mid-transfer
    seg0 = sched.link.time(job.segments[0].nbytes, 1)
    sched.pump(seg0 * 1.5)
    assert any(s.delivered for s in job.segments)
    assert not all(s.delivered for s in job.segments)
    sched.fail_node("D0")
    while not sched.idle():
        nxt = sched.next_event()
        assert nxt is not None, "scheduler stalled"
        sched.pump(nxt)
    assert job.state == "admitted" and job.dst is d1
    assert job.requeues == 1
    # partially-written blocks at D0 were released: nothing leaked
    assert d0.pool.free_blocks == POOL_KW["num_blocks"]
    assert d0.pool.invariant_ok() and d1.pool.invariant_ok()
    # bit-exact at the fallback node
    got = np.asarray(d1.pool.read_tokens(job.dst_blocks[:job.n_kv_blocks],
                                         13))
    want = np.concatenate([np.asarray(k), np.asarray(v)], -1)
    np.testing.assert_array_equal(got, want)


def test_draining_target_requeues_and_link_contention_serializes():
    """Two jobs share the P0->D0 link (FIFO, one in flight); D0 then
    drains mid-flight and both jobs fail over to D1 bit-exactly."""
    cfg, _ = reduced_params("granite-3-8b")
    rng = np.random.default_rng(9)
    d0 = SimpleNamespace(iid="D0", pool=PagedKVPool(cfg, **POOL_KW),
                         draining=False)
    d1 = SimpleNamespace(iid="D1", pool=PagedKVPool(cfg, **POOL_KW),
                         draining=False)
    sched = TransferScheduler(LinkModel(), pick_dst=lambda job: d1)
    jobs, wants = [], []
    for rid, tokens in ((0, 9), (1, 6)):
        req, out, k, v = _fake_job_inputs(cfg, rng, tokens, rid)
        jobs.append(sched.begin(req, out, src_iid="P0", dst=d0,
                                compute_s=0.0))
        wants.append((tokens,
                      np.concatenate([np.asarray(k), np.asarray(v)], -1)))
    seg0 = sched.link.time(jobs[0].segments[0].nbytes, 1)
    sched.pump(seg0 * 1.2)
    d0.draining = True
    while not sched.idle():
        nxt = sched.next_event()
        assert nxt is not None
        sched.pump(nxt)
    for job, (tokens, want) in zip(jobs, wants):
        assert job.state == "admitted" and job.dst is d1
        got = np.asarray(d1.pool.read_tokens(
            job.dst_blocks[:job.n_kv_blocks], tokens))
        np.testing.assert_array_equal(got, want)
    assert d0.pool.free_blocks == POOL_KW["num_blocks"]
    # FIFO contention: the shared link never had overlapping sends
    for link in sched.links.values():
        hist = sorted(link.history)
        assert all(a[1] <= b[0] + 1e-12 for a, b in zip(hist, hist[1:]))


def test_conflict_escalation_requeue_mid_pump():
    """Exhausting max_retries escalates the job to ANOTHER node from
    inside pump's link loop — which creates a brand-new (src,dst) link
    mid-iteration (regression: this crashed with 'dictionary changed
    size during iteration') — and must still deliver bit-exactly."""
    cfg, _ = reduced_params("granite-3-8b")
    rng = np.random.default_rng(1)
    d0 = SimpleNamespace(iid="D0", pool=PagedKVPool(cfg, **POOL_KW),
                         draining=False)
    d1 = SimpleNamespace(iid="D1", pool=PagedKVPool(cfg, **POOL_KW),
                         draining=False)
    sched = TransferScheduler(
        LinkModel(hops=2, conflict_prob=0.9), seed=3, max_retries=1,
        pick_dst=lambda job: d1 if job.dst is d0 else d0)
    req, out, k, v = _fake_job_inputs(cfg, rng, tokens=9, rid=0)
    job = sched.begin(req, out, src_iid="P0", dst=d0, compute_s=0.0)
    for _ in range(100_000):
        if sched.idle():
            break
        nxt = sched.next_event()
        assert nxt is not None
        sched.pump(nxt)
    assert job.state == "admitted"
    assert job.requeues > 0
    got = np.asarray(job.dst.pool.read_tokens(
        job.dst_blocks[:job.n_kv_blocks], 9))
    want = np.concatenate([np.asarray(k), np.asarray(v)], -1)
    np.testing.assert_array_equal(got, want)
    assert (d0 if job.dst is d1 else d1).pool.free_blocks \
        == POOL_KW["num_blocks"]


def test_multihop_conflicts_retry_and_still_deliver():
    """hops > 1 with a high conflict probability: segments fail and
    retry (bounded per segment before escalating) but delivery stays
    bit-exact and nothing is lost."""
    cfg, _ = reduced_params("granite-3-8b")
    rng = np.random.default_rng(2)
    d0 = SimpleNamespace(iid="D0", pool=PagedKVPool(cfg, **POOL_KW),
                         draining=False)
    d1 = SimpleNamespace(iid="D1", pool=PagedKVPool(cfg, **POOL_KW),
                         draining=False)
    link = LinkModel(hops=3, conflict_prob=0.4)
    sched = TransferScheduler(link, seed=7,
                              pick_dst=lambda job: d1 if job.dst is d0
                              else d0)
    req, out, k, v = _fake_job_inputs(cfg, rng, tokens=10, rid=0)
    job = sched.begin(req, out, src_iid="P0", dst=d0, compute_s=0.0)
    for _ in range(10_000):
        if sched.idle():
            break
        nxt = sched.next_event()
        assert nxt is not None
        sched.pump(nxt)
    assert job.state == "admitted"
    assert sched.n_retries > 0
    got = np.asarray(job.dst.pool.read_tokens(
        job.dst_blocks[:job.n_kv_blocks], 10))
    want = np.concatenate([np.asarray(k), np.asarray(v)], -1)
    np.testing.assert_array_equal(got, want)


def test_restore_node_reopens_transfer_target():
    """Regression: ``failed_nodes`` was a ONE-WAY set — a node that
    recovered could never be a transfer target again for the rest of
    the process lifetime. fail -> recover -> the transfer must land."""
    cfg, _ = reduced_params("granite-3-8b")
    rng = np.random.default_rng(6)
    d0 = SimpleNamespace(iid="D0", pool=PagedKVPool(cfg, **POOL_KW),
                         draining=False)
    sched = TransferScheduler(
        LinkModel(),
        pick_dst=lambda job: None if "D0" in sched.failed_nodes else d0)
    sched.fail_node("D0")
    req, out, k, v = _fake_job_inputs(cfg, rng, tokens=9, rid=1)
    job = sched.begin(req, out, src_iid="P0", dst=d0, compute_s=0.0)
    # the dead target strands the job: requeued with nowhere to go
    sched.pump(sched.now + 1.0)
    assert job.state == "waiting_dst"
    assert d0.pool.free_blocks == POOL_KW["num_blocks"]   # released
    # recovery: the node may take transfers again
    sched.restore_node("D0")
    assert sched.n_restores == 1
    sched.restore_node("D0")                    # idempotent
    assert sched.n_restores == 1
    for _ in range(10_000):
        if sched.idle():
            break
        nxt = sched.next_event()
        if nxt is None:
            sched.pump(sched.now + 1.0)
            continue
        sched.pump(nxt)
    assert job.state == "admitted" and job.dst is d0
    got = np.asarray(d0.pool.read_tokens(job.dst_blocks[:job.n_kv_blocks],
                                         9))
    want = np.concatenate([np.asarray(k), np.asarray(v)], -1)
    np.testing.assert_array_equal(got, want)
    assert d0.pool.invariant_ok()


def test_fail_src_drops_jobs_and_releases_dst_blocks():
    """A SOURCE (prefill) crash dooms the jobs it was feeding — nothing
    can re-send their buffers — but peers' jobs keep flowing and the
    partially-written dst blocks are released exactly once."""
    cfg, _ = reduced_params("granite-3-8b")
    rng = np.random.default_rng(8)
    d0 = SimpleNamespace(iid="D0", pool=PagedKVPool(cfg, **POOL_KW),
                         draining=False)
    sched = TransferScheduler(LinkModel(), pick_dst=lambda job: d0)
    req0, out0, _, _ = _fake_job_inputs(cfg, rng, tokens=12, rid=0)
    req1, out1, k1, v1 = _fake_job_inputs(cfg, rng, tokens=7, rid=1)
    j0 = sched.begin(req0, out0, src_iid="P0", dst=d0, compute_s=0.0)
    j1 = sched.begin(req1, out1, src_iid="P1", dst=d0, compute_s=0.0)
    sched.pump(sched.link.time(j0.segments[0].nbytes, 1) * 1.5)
    doomed = sched.fail_src("P0")
    assert doomed == [j0] and j0.state == "failed_src"
    assert not j0.dst_blocks and not j0.buf
    assert sched.n_src_failed == 1
    while not sched.idle():
        nxt = sched.next_event()
        assert nxt is not None, "scheduler stalled"
        sched.pump(nxt)
    assert j1.state == "admitted"
    got = np.asarray(d0.pool.read_tokens(j1.dst_blocks[:j1.n_kv_blocks],
                                         7))
    want = np.concatenate([np.asarray(k1), np.asarray(v1)], -1)
    np.testing.assert_array_equal(got, want)
    d0.pool.release(1)
    assert d0.pool.invariant_ok()
    assert d0.pool.free_blocks == POOL_KW["num_blocks"]   # no leak


def test_flap_link_retransmits_in_flight_segment():
    """A link outage window loses the in-flight message; it retransmits
    after the flap, delivery stays bit-exact and deterministic."""
    cfg, _ = reduced_params("granite-3-8b")
    rng = np.random.default_rng(12)
    d0 = SimpleNamespace(iid="D0", pool=PagedKVPool(cfg, **POOL_KW),
                         draining=False)
    sched = TransferScheduler(LinkModel(), pick_dst=lambda job: d0)
    req, out, k, v = _fake_job_inputs(cfg, rng, tokens=11, rid=2)
    job = sched.begin(req, out, src_iid="P0", dst=d0, compute_s=0.0)
    seg0 = sched.link.time(job.segments[0].nbytes, 1)
    sched.pump(seg0 * 1.5)           # first segment landed, next in flight
    t_flap, dur = sched.now, 0.05
    sched.flap_link("P0", "D0", t_flap, dur)
    assert sched.n_flaps == 1
    while not sched.idle():
        nxt = sched.next_event()
        assert nxt is not None
        sched.pump(nxt)
    assert job.state == "admitted"
    # the interrupted segment could only finish AFTER the outage window
    assert job.admitted_t >= t_flap + dur
    got = np.asarray(d0.pool.read_tokens(job.dst_blocks[:job.n_kv_blocks],
                                         11))
    want = np.concatenate([np.asarray(k), np.asarray(v)], -1)
    np.testing.assert_array_equal(got, want)
