"""Fused speculative decode (the multi-token jitted step).

Greedy speculation is LOSSLESS, so the fused propose/verify program
must be token-identical to plain fused greedy decode for every family
it is enabled on — through slot churn, warm prefix-reuse admissions,
and per-slot acceptance counts that vary step to step — while keeping
the paged pool BIT-identical to the plain path (rejected positions
never land in pool storage) and never retracing on how many tokens a
step happens to retire (acceptance is data, not shape).
"""
import gc

import jax
import numpy as np
import pytest

from conftest import reduced_params
from parity_utils import BS, decode_setup as _setup
from repro.models.modeling import spec_decode_step_cache_size
from repro.serving.engine import DecodeEngine, PrefillEngine
from repro.serving.kvcache import PagedKVPool
from repro.serving.speculative import (SpecConfig, SpeculativeDecoder,
                                       draft_for)

# every decoder-only family (enc-dec is gated out: the draft would need
# its own encoder pass per admission — see the engine assert)
FAMILIES = ["granite-3-8b", "qwen2-moe-a2.7b", "mamba2-2.7b",
            "jamba-1.5-large-398b"]


@pytest.fixture(autouse=True, scope="module")
def _fresh_compiler_state():
    """Same workaround as test_speculative: the b=1 oracle test below
    compiles the big EAGER decode scan, and deep into a full-suite run
    the XLA CPU compiler segfaults on it under the hundreds of live
    executables the earlier suites accumulated — drop them first."""
    jax.clear_caches()
    gc.collect()
    yield


def _admit(pool, de, rid, out, prompt, room=12):
    """Spec-mode twin of parity_utils.admit: spec admissions also carry
    the prompt (the draft prefills at the decode node)."""
    pool.alloc(rid, out.prompt_len + room)
    if out.k is not None:
        pool.write_prefill(
            pool.owned(rid)[: (out.prompt_len + BS - 1) // BS],
            out.k, out.v)
    return de.admit(rid, out, pool.owned(rid),
                    prompt=prompt if de.spec is not None else None)


def _plain_streams(cfg, params, outs, prompts, *, steps, room=20):
    """Reference: plain fused greedy stream per request."""
    pool = PagedKVPool(cfg, num_blocks=64, block_size=BS)
    de = DecodeEngine(cfg, params, pool, max_slots=len(outs))
    gen = {}
    for rid, out in enumerate(outs):
        _admit(pool, de, rid, out, prompts[rid], room=room)
        gen[rid] = [out.first_token]
    for _ in range(steps):
        for slot, tok in de.step().items():
            gen[de.rid[slot]].append(tok)
    return gen


@pytest.mark.parametrize("arch", FAMILIES)
def test_spec_matches_plain_fused_with_slot_churn(arch):
    """Fused-spec streams under admit/evict churn must be prefixes of
    the plain fused greedy streams — with an IMPERFECT draft, so
    acceptance genuinely varies (rounds emit 1..k+1 tokens)."""
    cfg, params, prompts, _ = _setup(arch)
    pe = PrefillEngine(cfg, params)
    outs = pe.run(prompts)
    spec = draft_for(cfg, seed=99)
    spec = SpecConfig(spec.draft_cfg, spec.draft_params, k=3)

    pool = PagedKVPool(cfg, num_blocks=48, block_size=BS)
    de = DecodeEngine(cfg, params, pool, max_slots=3, spec=spec)
    gen = {rid: [out.first_token] for rid, out in enumerate(outs)}

    def steps(n):
        for _ in range(n):
            for slot, toks in de.step().items():
                gen[de.rid[slot]].extend(toks)

    # room covers the worst case of 4 steps x (k+1) accepted tokens
    slot0 = _admit(pool, de, 0, outs[0], prompts[0], room=20)
    _admit(pool, de, 1, outs[1], prompts[1], room=20)
    steps(2)
    _admit(pool, de, 2, outs[2], prompts[2], room=20)  # admitted mid-flight
    steps(1)
    de.evict(slot0)                              # rid 0 leaves mid-flight
    pool.release(0)
    steps(1)
    assert de.spec_steps == 4
    assert de.spec_emitted == sum(len(g) - 1 for g in gen.values())

    plain = _plain_streams(cfg, params, outs, prompts, steps=16)
    for rid, got in gen.items():
        assert len(got) >= 3, (arch, rid)        # ≥1 token/slot/step
        assert got == plain[rid][:len(got)], (arch, rid)


def test_spec_matches_plain_on_warm_prefix_admission():
    """A suffix-only (prefix-reuse) prefill feeds both paths the same
    stitched KV; spec emission must still match plain greedy."""
    import jax.numpy as jnp
    cfg, params, _, _ = _setup("granite-3-8b")
    rng = np.random.default_rng(11)
    prefix = list(map(int, rng.integers(0, cfg.vocab_size, 8)))
    suffix = list(map(int, rng.integers(0, cfg.vocab_size, 5)))
    pe = PrefillEngine(cfg, params)
    cold, = pe.run([prefix + suffix])
    prefix_kv = jnp.concatenate([cold.k[:, :8], cold.v[:, :8]], axis=-1)
    warm = pe.run_suffix(suffix, prefix_kv)
    assert warm.first_token == cold.first_token
    spec = draft_for(cfg, seed=99)
    gens = {}
    for sp in (None, spec):
        pool = PagedKVPool(cfg, num_blocks=48, block_size=BS)
        de = DecodeEngine(cfg, params, pool, max_slots=2, spec=sp)
        _admit(pool, de, 0, warm, prefix + suffix)
        gen = [warm.first_token]
        while len(gen) < 6:
            got = de.step()[0]
            gen.extend(got if isinstance(got, list) else [got])
        gens[sp is None] = gen[:6]
    assert gens[True] == gens[False]


def test_spec_acceptance_variation_causes_zero_retraces():
    """THE retrace guard: per-slot acceptance/emission counts are data
    lanes, not shapes. One compiled program serves steps whose slots
    retire different token counts (forced deterministically here via
    per-slot headroom clamps on a perfect draft: slots capped at 2 and
    4 tokens of room retire 2 and 4 tokens in the SAME step)."""
    cfg, params, _, _ = _setup("granite-3-8b")
    rng = np.random.default_rng(3)
    # prompt lengths chosen so rid 0's block-rounded cap leaves EXACTLY
    # 2 tokens of headroom (caps are BS multiples: 6 + 2 == 8 == 2*BS)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
               for n in (6, 7)]
    pe = PrefillEngine(cfg, params)
    outs = pe.run(prompts)
    spec = SpecConfig(cfg, params, k=3)          # perfect draft: a == k
    pool = PagedKVPool(cfg, num_blocks=40, block_size=BS)
    de = DecodeEngine(cfg, params, pool, max_slots=2, spec=spec)
    # rid 0: room for exactly 2 more tokens (cap clamps emission to 2);
    # rid 1: plenty of room (emission k+1 = 4)
    slot0 = _admit(pool, de, 0, outs[0], prompts[0], room=2)
    slot1 = _admit(pool, de, 1, outs[1], prompts[1], room=17)
    base = spec_decode_step_cache_size()
    first = de.step()                            # compiles the program
    assert spec_decode_step_cache_size() - base == 1
    assert len(first[slot0]) == 2 and len(first[slot1]) == 4
    de.evict(slot0)                              # rid 0 is out of room
    pool.release(0)
    seen = {2, 4}
    for _ in range(2):
        for slot, toks in de.step().items():
            seen.add(len(toks))
    # varying emission counts, slot-set changes, zero recompiles
    assert spec_decode_step_cache_size() - base == 1
    assert len(seen) >= 2


def test_spec_pool_stays_bit_identical_to_plain():
    """Rejected positions never touch pool storage: the committed
    region matches plain greedy decode bit-for-bit and everything past
    it is still zero (the verify sweep's uncommitted writes were
    restored) on a fresh zero-filled pool."""
    cfg, params, prompts, _ = _setup("granite-3-8b")
    pe = PrefillEngine(cfg, params)
    out, = pe.run(prompts[:1])
    pl = out.prompt_len
    spec = draft_for(cfg, seed=99)               # imperfect: rejections
    k = spec.k

    pool_s = PagedKVPool(cfg, num_blocks=48, block_size=BS)
    de_s = DecodeEngine(cfg, params, pool_s, max_slots=1, spec=spec)
    _admit(pool_s, de_s, 0, out, prompts[0], room=12)
    emitted = de_s.step()[0]
    n = len(emitted)
    assert n < k + 1, "seed gave a fully-accepting draft; pick another"

    pool_p = PagedKVPool(cfg, num_blocks=48, block_size=BS)
    de_p = DecodeEngine(cfg, params, pool_p, max_slots=1)
    _admit(pool_p, de_p, 0, out, prompts[0], room=12)
    for _ in range(n):
        de_p.step()

    # committed region: identical to plain, bit for bit. Both engines
    # have written KV for positions [0, pl + n) (write-then-attend: the
    # last emitted token's KV lands on the NEXT step).
    a = np.asarray(pool_s.read_tokens(pool_s.owned(0), pl + n))
    b = np.asarray(pool_p.read_tokens(pool_p.owned(0), pl + n))
    assert np.array_equal(a, b)
    # uncommitted region: the verify sweep wrote positions up to
    # pl + k, but everything past the commit point was restored to the
    # fresh pool's zeros
    cap = len(pool_s.owned(0)) * BS
    tail = np.asarray(pool_s.read_tokens(pool_s.owned(0), cap))[:, pl + n:]
    assert not tail.any()


def test_spec_engine_matches_b1_oracle():
    """The fixed SpeculativeDecoder is the b=1 reference oracle: same
    draft, same k — the fused engine must emit its exact stream."""
    cfg, params = reduced_params("granite-3-8b")
    rng = np.random.default_rng(21)
    prompt = list(map(int, rng.integers(0, cfg.vocab_size, 9)))
    spec = draft_for(cfg, seed=99)
    n = 10
    oracle = SpeculativeDecoder(cfg, params, spec.draft_cfg,
                                spec.draft_params, k=spec.k)
    want = oracle.generate(prompt, n)

    pe = PrefillEngine(cfg, params)
    out, = pe.run([prompt])
    pool = PagedKVPool(cfg, num_blocks=48, block_size=BS)
    de = DecodeEngine(cfg, params, pool, max_slots=1, spec=spec)
    _admit(pool, de, 0, out, prompt, room=n + spec.k + 2)
    got = [out.first_token]
    while len(got) < n:
        got.extend(de.step()[0])
    assert got[:n] == want


def test_spec_rejects_encoder_decoder():
    cfg, params = reduced_params("whisper-base")
    pool = PagedKVPool(cfg, num_blocks=16, block_size=BS)
    with pytest.raises(AssertionError, match="enc-dec"):
        DecodeEngine(cfg, params, pool, spec=SpecConfig(cfg, params))


def test_draft_for_is_scenario_aware():
    """Scenario-aware draft pairing: a small same-vocab config family
    drafting for the large one, with speculation depth picked per
    scenario group (output-length statistics are per-scenario, §3.2)."""
    from repro.models.params import block_period
    cfg, _ = reduced_params("granite-3-8b")
    a = draft_for(cfg, "write")
    b = draft_for(cfg, "summarize")
    assert a.k > b.k                             # long-gen drafts deeper
    assert a.draft_cfg.vocab_size == cfg.vocab_size
    assert a.draft_cfg.num_layers < cfg.num_layers
    # hybrid periods survive the depth cut (the reduced jamba is a
    # single period deep, so its smallest valid draft keeps full depth)
    hcfg, _ = reduced_params("jamba-1.5-large-398b")
    h = draft_for(hcfg)
    assert h.draft_cfg.num_layers % block_period(hcfg) == 0
    assert h.draft_cfg.num_layers <= hcfg.num_layers
