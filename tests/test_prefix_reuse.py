"""Block-level prefix KV reuse on the real serving path (paper §2.2.1).

Warm (prefix-hit, suffix-only) serving must emit token-identical output
to a cold run, while the compute-token counter proves the forward pass
covered only the uncached suffix. One config per family: dense / MoE /
encoder-decoder reuse KV alone; SSM/hybrid (jamba) and attention-free
(mamba2) stacks additionally restore the boundary recurrent-state
snapshot (PR 6 — bit-level state parity is pinned in
tests/test_state_snapshot_reuse.py; here the serving-path contract).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_params
from parity_utils import POOL_KW, family_setup, prefill_node, \
    serve_sequential
from repro.kernels import ref
from repro.kernels.flash_prefill import flash_prefill_pallas
from repro.serving.kvcache import PagedKVPool, PoolExhausted

# families where suffix-only reuse is KV-only; SSM/hybrid families ride
# the same path plus a state-snapshot restore (tested below)
REUSE_ARCHS = ["granite-3-8b", "qwen2-moe-a2.7b", "whisper-base"]
STATE_ARCHS = ["jamba-1.5-large-398b", "mamba2-2.7b"]


def _serve(cfg, params, prompts, *, prefix_cache, frames=None, max_new=3):
    gens, fe = serve_sequential(cfg, params, prompts,
                                prefix_cache=prefix_cache, frames=frames,
                                max_new=max_new)
    return gens, prefill_node(fe)


@pytest.mark.parametrize("arch", REUSE_ARCHS)
def test_warm_matches_cold_and_computes_suffix_only(arch):
    rng = np.random.default_rng(3)
    cfg, params, frames = family_setup(arch, rng)
    prefix = list(map(int, rng.integers(0, cfg.vocab_size, 12)))
    suffixes = [list(map(int, rng.integers(0, cfg.vocab_size, 5)))
                for _ in range(3)]
    prompts = [prefix + s for s in suffixes]
    cold, cn = _serve(cfg, params, prompts, prefix_cache=False,
                      frames=frames)
    warm, wn = _serve(cfg, params, prompts, prefix_cache=True,
                      frames=frames)
    assert warm == cold                              # token parity
    # cold computed every prompt token; warm computed the seed request in
    # full and ONLY the uncached suffix afterwards (12-token prefix = 3
    # full 4-token blocks)
    assert cn.engine.compute_tokens == sum(len(p) for p in prompts)
    assert wn.engine.compute_tokens == len(prompts[0]) + sum(
        len(p) - 12 for p in prompts[1:])
    assert wn.engine.prefix_prefills == len(prompts) - 1
    assert wn.engine.reused_tokens == 12 * (len(prompts) - 1)
    assert wn.pool.hits == len(prompts) - 1
    assert wn.pool.invariant_ok()


@pytest.mark.parametrize("arch", STATE_ARCHS)
def test_ssm_families_serve_warm_with_state_restore(arch):
    """SSM/hybrid stacks carry recurrent state a KV prefix alone cannot
    restore: the index stays ON and a snapshot restore rides each hit.
    Hits land on snapshot-stride boundaries, so the reused span is the
    prefix rounded DOWN to the node's stride."""
    rng = np.random.default_rng(4)
    cfg, params, frames = family_setup(arch, rng, sorted_moe=False)
    prefix = list(map(int, rng.integers(0, cfg.vocab_size, 35)))
    prompts = [prefix + list(map(int, rng.integers(0, cfg.vocab_size, 4)))
               for _ in range(2)]
    cold, cn = _serve(cfg, params, prompts, prefix_cache=False,
                      frames=frames, max_new=2)
    warm, wn = _serve(cfg, params, prompts, prefix_cache=True,
                      frames=frames, max_new=2)
    assert warm == cold
    assert wn.prefix_cache and wn.needs_state
    stride = wn.snap_stride
    assert stride and stride % cfg.ssm_cfg.chunk == 0
    # 35-token prefix degrades to the 32-boundary snapshot
    reused = 35 - 35 % stride
    assert wn.pool.hits == 1 and wn.pool.snap_hits == 1
    assert wn.engine.state_restores == 1
    assert wn.engine.reused_tokens == reused
    assert wn.engine.compute_tokens == \
        cn.engine.compute_tokens - reused
    assert wn.pool.invariant_ok()


def test_capacity_moe_joins_the_index_window_aligned():
    """Capacity dispatch went window-local and row-length-independent
    (PR 5): the capacity-MoE gate on the prefix index is lifted. Hits
    must land on capacity-window boundaries — the engine advertises the
    alignment and the pool's aligned acquire rounds hits down to it
    (warm-vs-cold token parity for capacity MoE is pinned in
    tests/test_bucketed_prefill.py)."""
    from repro.serving.engine import PrefillEngine
    cfg, params = reduced_params("qwen2-moe-a2.7b")
    assert cfg.moe.dispatch == "capacity"
    eng = PrefillEngine(cfg, params)
    assert eng.supports_prefix_reuse
    assert eng.prefix_align == cfg.moe.capacity_window
    sorted_cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                     dispatch="sorted"))
    eng_s = PrefillEngine(sorted_cfg, params)
    assert eng_s.supports_prefix_reuse and eng_s.prefix_align == 1


def test_aligned_acquire_rounds_down_to_window():
    """A 9-token trie match under align=8 degrades to an 8-token hit
    (whole-block + COW boundary respected); under align=16 it is a
    clean miss with no refs taken."""
    cfg, _ = reduced_params("granite-3-8b")
    pool = PagedKVPool(cfg, num_blocks=16, block_size=4,
                       enable_prefix_cache=True)
    toks = list(range(9)) + [50, 51]
    pool.alloc(0, len(toks))
    pool.insert_prefix(0, toks)
    assert pool.peek_prefix(toks + [7], align=8) == 8
    assert pool.peek_prefix(toks + [7], align=16) == 0
    got = pool.acquire_prefix(1, toks + [7], align=8)
    assert got == 8 and len(pool.owned(1)) == 2
    assert pool.invariant_ok()
    assert pool.acquire_prefix(2, toks + [7], align=16) == 0
    assert pool.owned(2) == [] and pool.invariant_ok()


def test_cow_exhaustion_degrade_stays_aligned():
    """When the COW tail cannot allocate, the degraded whole-block hit
    must still land on an align boundary (rolling back refs on dropped
    blocks) — run_suffix asserts the alignment at admission."""
    cfg, _ = reduced_params("granite-3-8b")
    pool = PagedKVPool(cfg, num_blocks=10, block_size=4,
                       enable_prefix_cache=True)
    toks = list(range(20))
    pool.alloc(0, len(toks))                 # 5 blocks, rid 0 stays live
    pool.insert_prefix(0, toks)
    pool.alloc(1, 20)                        # exhaust the other 5 blocks
    assert pool.free_blocks == 0
    # target 18 -> align 6 -> 18; match gives 4 full blocks + rem 2 ->
    # COW impossible -> degrade must drop to 12 (3 blocks), not 16
    cached = pool.acquire_prefix(2, toks[:19] + [99], align=6)
    assert cached == 12 and len(pool.owned(2)) == 3
    assert cached % 6 == 0
    assert pool.invariant_ok()
    pool.release(2)
    # align=32: nothing aligned fits under the 19-token limit -> clean
    # miss, no refs taken
    assert pool.acquire_prefix(3, toks[:19] + [99], align=32) == 0
    assert pool.owned(3) == [] and pool.invariant_ok()


def test_attn_free_indexes_zero_width_blocks():
    """No attention layers -> blocks carry no KV payload, but the trie
    still indexes them as KEY HOLDERS so state snapshots have blocks to
    ride on: attention-free stacks now reuse prefixes via snapshots
    instead of bypassing the index."""
    rng = np.random.default_rng(5)
    cfg, params = reduced_params("mamba2-2.7b")
    prefix = list(map(int, rng.integers(0, cfg.vocab_size, 33)))
    prompts = [prefix + list(map(int, rng.integers(0, cfg.vocab_size, 3)))
               for _ in range(2)]
    cold, _ = _serve(cfg, params, prompts, prefix_cache=False, max_new=2)
    warm, wn = _serve(cfg, params, prompts, prefix_cache=True, max_new=2)
    assert warm == cold
    assert wn.prefix_cache and wn.pool.lookups > 0
    assert wn.pool.attn_layers == 0          # zero-width KV blocks
    assert wn.pool.hits == 1 and wn.pool.snap_hits == 1
    assert wn.engine.state_restores == 1


def test_cow_tail_partial_prefix():
    """A prefix that ends mid-block forces a copy-on-write of the tail
    block; the shared source block must stay untouched."""
    rng = np.random.default_rng(6)
    cfg, params = reduced_params("granite-3-8b")
    prefix = list(map(int, rng.integers(0, cfg.vocab_size, 9)))  # 9 % 4 != 0
    prompts = [prefix + list(map(int, rng.integers(0, cfg.vocab_size, 4)))
               for _ in range(3)]
    cold, _ = _serve(cfg, params, prompts, prefix_cache=False)
    warm, wn = _serve(cfg, params, prompts, prefix_cache=True)
    assert warm == cold
    assert wn.pool.cow_copies >= 1
    assert wn.pool.invariant_ok()


def test_enc_dec_frames_partition_the_index():
    """Same decoder prefix but different frames must NOT share KV (the
    decoder hidden states depend on the encoder output)."""
    from repro.serving.cluster import ServeRequest
    from repro.serving.frontend import ClusterFrontend
    rng = np.random.default_rng(7)
    cfg, params = reduced_params("whisper-base")
    prefix = list(map(int, rng.integers(0, cfg.vocab_size, 8)))
    prompts = [prefix + list(map(int, rng.integers(0, cfg.vocab_size, 4)))
               for _ in range(2)]
    fr1 = np.asarray(rng.normal(size=(cfg.encoder_seq, cfg.d_model)) * 0.1,
                     np.float32)
    fr2 = np.asarray(rng.normal(size=(cfg.encoder_seq, cfg.d_model)) * 0.1,
                     np.float32)
    fe = ClusterFrontend(cfg, topology={"default": (1, 1)}, params=params,
                         prefill_kwargs=dict(POOL_KW),
                         decode_kwargs=dict(POOL_KW))
    gens = {}
    for i, (toks, fr) in enumerate(
            [(prompts[0], fr1), (prompts[1], fr2), (prompts[1], fr2)]):
        req = ServeRequest(rid=i, tokens=list(toks), max_new_tokens=2,
                           frames=fr)
        fe.run([req], max_ticks=80)
        gens[i] = list(req.generated)
    node = prefill_node(fe)
    # request 1 (different frames) missed; request 2 (same frames as 1) hit
    assert node.pool.hits == 1
    # cross-check against cold single-request serving
    cold, _ = _serve(cfg, params, [prompts[1]], prefix_cache=False,
                     frames=fr2, max_new=2)
    assert gens[2] == cold[0]


def test_flash_prefill_kernel_query_offset():
    """Pallas suffix-prefill (query offset) matches the oracle with a
    prefix KV longer than the query span."""
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.normal(size=(2, 128, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 256, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 256, 32)), jnp.float32)
    got = flash_prefill_pallas(q, k, v, q_tile=64, kv_tile=64,
                               interpret=True, q_offset=128)
    want = ref.flash_prefill(q, k, v, q_offset=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_eviction_under_pressure_frees_cached_blocks():
    """Refcount-0 prefix blocks are LRU-evicted instead of raising
    PoolExhausted; blocks a live request holds are never evicted."""
    cfg, _ = reduced_params("granite-3-8b")
    pool = PagedKVPool(cfg, num_blocks=8, block_size=4,
                       enable_prefix_cache=True)
    toks_a = list(range(16))                 # 4 blocks
    pool.alloc(0, len(toks_a))
    pool.insert_prefix(0, toks_a)
    pool.release(0)                          # cached, refcount 0
    assert pool.cached_blocks == 4 and pool.free_blocks == 4
    pool.alloc(1, 24)                        # 6 blocks: needs 2 evictions
    assert pool.free_blocks == 0 and pool.evictions == 2
    assert pool.invariant_ok()
    pool.release(1)                          # private blocks -> free again
    # a LIVE holder pins its blocks: exhaust instead of evict
    cached = pool.acquire_prefix(2, toks_a[:8] + [99])   # shares 2 blocks
    assert cached == 8
    pool.alloc_to(2, 9)
    with pytest.raises(PoolExhausted):
        pool.alloc(3, 40)
    assert set(pool.owned(2)[:2]) <= set(pool._cached)   # still cached
    assert pool.invariant_ok()


def test_cow_exhaustion_degrades_without_leaking_refs():
    """When the pool cannot allocate the COW tail block, acquire must
    degrade to the whole-block hit (or a miss) and roll back any
    refcounts it took — not raise with dangling references."""
    cfg, _ = reduced_params("granite-3-8b")
    pool = PagedKVPool(cfg, num_blocks=6, block_size=4,
                       enable_prefix_cache=True)
    toks = list(range(10))                   # 2 full blocks + partial 2
    pool.alloc(0, len(toks))                 # 3 blocks
    pool.insert_prefix(0, toks)              # rid 0 stays live (pinned)
    pool.alloc(1, 12)                        # exhaust the other 3 blocks
    assert pool.free_blocks == 0
    # full-block + partial-tail match, but no block free and nothing
    # evictable -> COW impossible: degrade to the 8-token hit
    cached = pool.acquire_prefix(2, toks[:9] + [77, 78])
    assert cached == 8 and len(pool.owned(2)) == 2
    assert pool.invariant_ok()
    pool.release(2)
    assert pool.invariant_ok()
    # same situation with NO full block available: clean miss, no refs
    pool2 = PagedKVPool(cfg, num_blocks=2, block_size=4,
                        enable_prefix_cache=True)
    pool2.alloc(0, 3)
    pool2.insert_prefix(0, [5, 6, 7])        # partial-only cache, live
    pool2.alloc(1, 4)                        # exhausted
    assert pool2.acquire_prefix(2, [5, 6, 9]) == 0
    assert pool2.owned(2) == [] and pool2.invariant_ok()
