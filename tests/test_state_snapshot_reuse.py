"""Recurrent-state snapshot reuse (PR 6): SSM/hybrid prefix hits.

The contract under test, per family (pure SSM: mamba2; hybrid
attn+SSM+capacity-MoE: jamba; hybrid with dropless MoE): a warm
suffix-only prefill restored from a boundary snapshot must be

  * token-identical to the cold full prefill (first token AND the
    decode stream it seeds, fused and eager);
  * bit-identical in recurrent state at decode hand-off — conv tails
    (x/B/C windows) and the SSD inter-chunk state, every layer;
  * bit-identical in the KV it stitches for attention layers and in the
    snapshots it RE-EMITS at later boundaries (chained reuse);

with hits landing only on snapshot-stride boundaries (non-boundary cuts
degrade to the nearest boundary DOWN, never a COW tail), and warm
admissions reusing the compiled suffix program across waves.
"""
import dataclasses
import math

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_params
from parity_utils import BS, admit, assert_state_equal, prefill_node, \
    serve_sequential
from repro.serving.engine import DecodeEngine, PrefillEngine, \
    prefill_compile_count
from repro.serving.kvcache import PagedKVPool

# (arch, MoE dispatch override): pure SSM / hybrid + capacity MoE /
# hybrid + dropless sorted MoE — param shapes identical across dispatch
VARIANTS = [
    ("mamba2-2.7b", None),
    ("jamba-1.5-large-398b", None),
    ("jamba-1.5-large-398b", "sorted"),
]
IDS = ["mamba2", "jamba-capacity", "jamba-sorted"]

def _family(arch, dispatch):
    cfg, params = reduced_params(arch)
    if dispatch is not None and cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  dispatch=dispatch))
    return cfg, params


def _pkv(out, plen):
    if out.k is None:
        return None
    return jnp.concatenate([out.k[:, :plen], out.v[:, :plen]], axis=-1)


def _prompt(cfg, rng, n):
    return list(map(int, rng.integers(0, cfg.vocab_size, n)))


@pytest.mark.parametrize("arch,dispatch", VARIANTS, ids=IDS)
def test_warm_restore_is_bitwise_at_every_boundary(arch, dispatch):
    """Engine-level pin: restore from EACH emitted boundary; outputs
    token-identical, stitched KV + full recurrent state + re-emitted
    later snapshots bitwise. The short-suffix leg (suffix < conv k-1)
    forces the conv window to straddle the restore boundary."""
    cfg, params = reduced_params(arch) if dispatch is None \
        else _family(arch, dispatch)
    pe = PrefillEngine(cfg, params)
    assert pe.supports_prefix_reuse and pe.requires_state_restore
    stride = pe.prefix_align
    assert stride % cfg.ssm_cfg.chunk == 0
    rng = np.random.default_rng(17)
    for suffix_len in (7, 2):            # 2 < conv_width-1 for d_conv 4
        prompt = _prompt(cfg, rng, 2 * stride + suffix_len)
        cold, = pe.run([prompt], snap_stride=stride)
        assert set(cold.snapshots) == {stride, 2 * stride}
        for boundary in (stride, 2 * stride):
            warm = pe.run_suffix(
                prompt[boundary:], _pkv(cold, boundary),
                state=cold.snapshots[boundary], prefix_len=boundary,
                snap_stride=stride)
            ctx = (arch, dispatch, suffix_len, boundary)
            assert warm.first_token == cold.first_token, ctx
            assert warm.prompt_len == cold.prompt_len, ctx
            if cold.k is not None:
                assert np.array_equal(np.asarray(cold.k),
                                      np.asarray(warm.k)), ctx
                assert np.array_equal(np.asarray(cold.v),
                                      np.asarray(warm.v)), ctx
            assert_state_equal(cold.mamba_state, warm.mamba_state,
                               ctx=str(ctx))
            # boundaries re-emitted over the suffix chain bitwise
            for t, snap in (warm.snapshots or {}).items():
                assert_state_equal(cold.snapshots[t], snap,
                                   ctx=f"{ctx} snap@{t}")
            assert pe.state_restores > 0


@pytest.mark.parametrize("arch,dispatch", VARIANTS, ids=IDS)
def test_decode_handoff_from_restored_state(arch, dispatch):
    """The restored-and-advanced warm state admits into decode (fused
    AND eager) producing the cold stream exactly."""
    cfg, params = _family(arch, dispatch)
    pe = PrefillEngine(cfg, params)
    stride = pe.prefix_align
    rng = np.random.default_rng(23)
    prompt = _prompt(cfg, rng, stride + 5)
    cold, = pe.run([prompt], snap_stride=stride)
    warm = pe.run_suffix(prompt[stride:], _pkv(cold, stride),
                         state=cold.snapshots[stride], prefix_len=stride,
                         snap_stride=stride)
    for fused in (False, True):
        streams = []
        for out in (cold, warm):
            pool = PagedKVPool(cfg, num_blocks=48, block_size=BS)
            de = DecodeEngine(cfg, params, pool, max_slots=2, fused=fused)
            admit(pool, de, 0, out)
            gen = [out.first_token]
            for _ in range(4):
                gen.append(de.step()[0])
            streams.append(gen)
        assert streams[0] == streams[1], (arch, dispatch, fused)


@pytest.mark.parametrize("arch,dispatch", VARIANTS, ids=IDS)
def test_warm_serving_matches_cold_through_frontend(arch, dispatch):
    """End to end through ClusterFrontend: SSM-family warm serving is
    token-identical to cold, the snapshot index records the hits, and
    the transfer scheduler ships the restored state segment."""
    cfg, params = _family(arch, dispatch)
    rng = np.random.default_rng(29)
    prefix = _prompt(cfg, rng, 35)
    prompts = [prefix + _prompt(cfg, rng, 5) for _ in range(3)]
    cold, _ = serve_sequential(cfg, params, prompts, prefix_cache=False,
                               max_new=2)
    warm, fe = serve_sequential(cfg, params, prompts, prefix_cache=True,
                                max_new=2)
    assert warm == cold
    node = prefill_node(fe)
    stride = node.snap_stride
    assert stride and stride % BS == 0
    reused = 35 - 35 % stride            # non-boundary cut degrades DOWN
    ps = fe.groups["default"].prefix_stats()
    assert ps["snap_hits"] == len(prompts) - 1
    assert ps["snap_stores"] >= 1 and ps["snap_bytes"] > 0
    assert ps["state_restores"] == len(prompts) - 1
    assert node.engine.reused_tokens == reused * (len(prompts) - 1)
    assert node.pool.invariant_ok()
    # every SSM admission carries a trailing state segment; the warm
    # ones ship the RESTORED state rather than a recomputed one
    ts = fe.groups["default"].transfer_stats()
    assert ts["state_segments"] >= len(prompts)
    assert ts["state_payload_bytes"] > 0


def test_non_boundary_cut_degrades_to_snapshot_boundary():
    """Pool-level floor semantics: a require_state acquire rounds an
    aligned trie match DOWN to the nearest boundary that still HOLDS a
    snapshot — stale boundaries (evicted snapshot) are skipped, and a
    prefix with no surviving boundary is a clean miss (counted)."""
    cfg, _ = reduced_params("granite-3-8b")
    pool = PagedKVPool(cfg, num_blocks=64, block_size=4,
                       enable_prefix_cache=True)
    toks = list(range(70))
    snap = lambda t: {"state": np.full((2, 2), float(t), np.float32)}
    pool.alloc(0, len(toks))
    pool.insert_prefix(0, toks, states={32: snap(32), 64: snap(64)})
    assert pool.snap_stores == 2
    # 70-token prompt, align 32: target 64, boundary 64 holds a snapshot
    got = pool.acquire_prefix(1, toks + [99], align=32, require_state=True)
    assert got == 64 and pool.snap_hits == 1
    assert pool.snapshot_for(1, got)["state"][0, 0] == 64.0
    # drop the 64-boundary snapshot (simulates its block being evicted):
    # the same acquire now floors to 32
    blk64 = pool.owned(1)[64 // 4 - 1]
    pool._snaps.pop(blk64)
    pool.release(1)
    got = pool.acquire_prefix(2, toks + [99], align=32, require_state=True)
    assert got == 32 and pool.snapshot_for(2, got)["state"][0, 0] == 32.0
    pool.release(2)
    # no surviving boundary at all -> clean miss, no refs, counted
    pool._snaps.clear()
    misses = pool.snap_misses
    got = pool.acquire_prefix(3, toks + [99], align=32, require_state=True)
    assert got == 0 and pool.owned(3) == []
    assert pool.snap_misses == misses + 1
    assert pool.invariant_ok()


def test_second_wave_reuses_compiled_suffix_program():
    """Zero-retrace guard: a second wave of warm restores with the same
    (prefix len, suffix bucket, stride) shapes — different tokens, a
    different boundary state — must not compile anything new."""
    cfg, params = reduced_params("jamba-1.5-large-398b")
    pe = PrefillEngine(cfg, params)
    stride = pe.prefix_align
    rng = np.random.default_rng(31)
    p1 = _prompt(cfg, rng, stride + 6)
    p2 = _prompt(cfg, rng, stride + 6)
    cold1, = pe.run([p1], snap_stride=stride)
    cold2, = pe.run([p2], snap_stride=stride)
    pe.run_suffix(p1[stride:], _pkv(cold1, stride),
                  state=cold1.snapshots[stride], prefix_len=stride,
                  snap_stride=stride)
    c0 = prefill_compile_count()
    hits0 = pe.bucket_hits
    warm2 = pe.run_suffix(p2[stride:], _pkv(cold2, stride),
                          state=cold2.snapshots[stride], prefix_len=stride,
                          snap_stride=stride)
    assert prefill_compile_count() == c0          # no retrace
    assert pe.bucket_hits == hits0 + 1            # telemetry saw reuse
    assert warm2.first_token == cold2.first_token
    assert_state_equal(cold2.mamba_state, warm2.mamba_state)


def test_snapshot_stride_is_lcm_of_block_chunk_and_window():
    """The serving node's stride must divide evenly into pool blocks,
    SSD chunks, and (when present) capacity windows — the invariant
    that makes require_state acquires land on whole-block, chunk-exact,
    window-exact boundaries (so restores are bitwise and never COW)."""
    for arch, dispatch in VARIANTS:
        cfg, params = _family(arch, dispatch)
        _, fe = serve_sequential(cfg, params, [[1, 2, 3]],
                                 prefix_cache=True, max_new=1)
        node = prefill_node(fe)
        assert node.needs_state
        want = math.lcm(node.engine.prefix_align, BS)
        assert node.snap_stride == node.prefix_align == want
        assert node.snap_stride % cfg.ssm_cfg.chunk == 0
        assert node.snap_stride % BS == 0
        if cfg.moe is not None and cfg.moe.dispatch == "capacity":
            assert node.snap_stride % cfg.moe.capacity_window == 0


def test_reuse_gate_follows_prefill_geometry():
    """The snapshot-reuse gate is a function of the prefill geometry:
    bucketed (the default — the env hatch is retired) => on (bitwise
    contract holds), exact-length via the ``bucket_prefill=False``
    constructor arg => off (no geometry control — a tiny suffix program
    wobbles the SSD state by ulps, and hybrids cannot pad without
    breaking the attention key geometry)."""
    cfg, params = reduced_params("mamba2-2.7b")
    pe = PrefillEngine(cfg, params)
    assert pe.bucket_prefill
    assert pe.supports_prefix_reuse
    assert pe.requires_state_restore
    for arch in ("mamba2-2.7b", "jamba-1.5-large-398b"):
        c, p = reduced_params(arch)
        assert not PrefillEngine(c, p,
                                 bucket_prefill=False).supports_prefix_reuse
        assert PrefillEngine(c, p,
                             bucket_prefill=True).supports_prefix_reuse
    # attention-only families reuse prefixes in EITHER geometry
    cg, pg = reduced_params("granite-3-8b")
    assert PrefillEngine(cg, pg, bucket_prefill=False).supports_prefix_reuse
