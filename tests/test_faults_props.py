"""Property tests: pool/scheduler accounting stays exact under
arbitrary crash / recover / re-admit interleavings (the faults.py
evacuation + substitute-integration primitives driven adversarially).

Invariants:
  * no PagedKVPool block is ever leaked or double-freed — the
    free/private/cached partition holds after every chaos action, and a
    final release returns every pool to fully free;
  * recurrent-state snapshots survive crash wipes in lockstep with
    their blocks (no orphan snapshot, no snap_bytes ledger leak);
  * links stay serial (one in-flight message) across flaps and crashes;
  * every job that ultimately lands is byte-identical to a direct copy,
    including jobs re-begun after their source node crashed (fail_src
    re-admit) and jobs displaced off a crashed destination (fail_node).

Each hypothesis property has an always-run seeded numpy mirror, so the
coverage survives environments without hypothesis (the conftest shim
skips @given tests there).
"""
from types import SimpleNamespace

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from conftest import reduced_params
from repro.core.transfer import LinkModel
from repro.serving.kvcache import PagedKVPool, PoolExhausted
from repro.serving.transfer_sched import TransferScheduler

NB = 64
BS = 4
ALIGN = 2 * BS


def _mk_dst(cfg, iid):
    return SimpleNamespace(iid=iid, draining=False,
                           pool=PagedKVPool(cfg, num_blocks=NB,
                                            block_size=BS))


def _assert_links_serial(sched):
    for link in sched.links.values():
        hist = sorted(link.history)
        assert all(a[1] <= b[0] + 1e-12 for a, b in zip(hist, hist[1:])), \
            link.key


# ------------------------------------------- scheduler chaos interleaving

def _chaos_core(seed: int):
    cfg, _ = reduced_params("granite-3-8b")
    rng = np.random.default_rng(seed)
    dsts = [_mk_dst(cfg, "D0"), _mk_dst(cfg, "D1")]
    healthy = {"D0", "D1"}

    def pick(job):
        cands = [d for d in dsts if d.iid in healthy and not d.draining]
        return cands[0] if cands else None

    sched = TransferScheduler(LinkModel(), seed=int(rng.integers(0, 999)),
                              pick_dst=pick)
    L = sum(1 for k in cfg.layer_kinds() if k == "attn")
    expected = {}                        # rid -> (tokens, want bytes)
    jobs = []

    def begin(rid, src, compute_s):
        if rid in expected:              # fail_src re-admit: same bytes
            tokens, want = expected[rid]
            k = jnp.asarray(want[..., :cfg.kv_dim])
            v = jnp.asarray(want[..., cfg.kv_dim:])
        else:
            tokens = int(rng.integers(1, 18))
            k = jnp.asarray(rng.normal(size=(L, tokens, cfg.kv_dim)),
                            jnp.float32)
            v = jnp.asarray(rng.normal(size=(L, tokens, cfg.kv_dim)),
                            jnp.float32)
        out = SimpleNamespace(k=k, v=v, prompt_len=tokens,
                              mamba_state={}, cross=None)
        req = SimpleNamespace(rid=rid, max_new_tokens=2)
        dst = pick(None)
        if dst is None:
            return None
        job = sched.begin(req, out, src_iid=src, dst=dst,
                          t_start=sched.now, compute_s=compute_s)
        jobs.append(job)
        expected[rid] = (tokens, np.concatenate(
            [np.asarray(k), np.asarray(v)], -1))
        return job

    rid_next = 100
    for _ in range(int(rng.integers(4, 14))):
        act = str(rng.choice(["begin", "begin", "pump", "crash_dst",
                              "restore", "crash_src", "flap"]))
        if act == "begin":
            begin(rid_next, str(rng.choice(["P0", "P1"])),
                  float(rng.choice([0.0, 0.01])))
            rid_next += 1
        elif act == "pump":
            sched.pump(sched.now + float(rng.uniform(0.0, 0.02)))
        elif act == "crash_dst":
            iid = str(rng.choice(["D0", "D1"]))
            if iid in healthy and len(healthy) > 1:
                healthy.discard(iid)
                sched.fail_node(iid)
        elif act == "restore":
            iid = str(rng.choice(["D0", "D1"]))
            healthy.add(iid)
            sched.restore_node(iid)
        elif act == "crash_src":
            src = str(rng.choice(["P0", "P1"]))
            resrc = "P1" if src == "P0" else "P0"
            for job in sched.fail_src(src):
                # the evacuation path: the dead source's requests
                # re-prefill on a healthy peer, byte-identical
                jobs.remove(job)
                assert job.state == "failed_src" and not job.dst_blocks
                begin(job.rid, resrc, 0.005)
        elif act == "flap":
            sched.flap_link("P0", "D0", sched.now,
                            float(rng.uniform(0.001, 0.01)))
        _assert_links_serial(sched)
        for d in dsts:
            assert d.pool.invariant_ok(), d.iid
    # drive to completion with everything healthy again
    for iid in ("D0", "D1"):
        healthy.add(iid)
        sched.restore_node(iid)
    for _ in range(100_000):
        if sched.idle():
            break
        nxt = sched.next_event()
        if nxt is None:
            sched.pump(sched.now + 1.0)
            if sched.next_event() is None and not sched.idle():
                raise AssertionError("scheduler stalled with no target")
            continue
        sched.pump(nxt)
    assert sched.idle()
    _assert_links_serial(sched)
    for job in jobs:
        assert job.state == "admitted"
        tokens, want = expected[job.rid]
        got = np.asarray(job.dst.pool.read_tokens(
            job.dst_blocks[:job.n_kv_blocks], tokens))
        np.testing.assert_array_equal(got, want)
    # releasing every admitted request must return BOTH pools to fully
    # free — any leaked or double-freed block breaks the accounting
    for job in jobs:
        job.dst.pool.release(job.rid)
    for d in dsts:
        assert d.pool.invariant_ok()
        assert d.pool.free_blocks == NB, d.iid


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 31))
def test_chaos_interleavings_no_leak(seed):
    _chaos_core(seed)


def test_chaos_interleavings_no_leak_seeded():
    """Always-run mirror of the hypothesis property (fixed seeds)."""
    for seed in (0, 1, 7, 23, 1337):
        _chaos_core(seed)


# ---------------------------------------- crash wipe x prefix snapshots

def _snap(t):
    return {"state": np.full((3,), float(t), np.float32),
            "conv_x": np.full((2, 2), float(t), np.float32)}


def _states_for(toks):
    return {t: _snap(t) for t in range(ALIGN, len(toks) + 1, ALIGN)}


def _snaps_consistent(pool):
    """No orphan (snapshot on a non-cached block) and no ledger leak."""
    assert set(pool._snaps) <= set(pool._cached)
    assert pool.snap_bytes == sum(pool._snap_nbytes(s)
                                  for s in pool._snaps.values())


def _wipe_core(seed: int, num_blocks: int = 16):
    """Prefill-node crash evacuation (release ALL owned rids at once)
    interleaved with snapshot-bearing prefix churn: the wipe must not
    orphan snapshots, double-free shared blocks, or leak the ledger."""
    cfg, _ = reduced_params("granite-3-8b")
    rng = np.random.default_rng(seed)
    pool = PagedKVPool(cfg, num_blocks=num_blocks, block_size=BS,
                       enable_prefix_cache=True)
    live = set()
    rid_next = 0
    for _ in range(30):
        op = str(rng.choice(["admit", "admit", "release", "wipe"]))
        if op == "release" and live:
            rid = sorted(live)[int(rng.integers(0, len(live)))]
            pool.release(rid)
            live.discard(rid)
        elif op == "wipe":
            # the faults.py _evacuate path: every owned rid goes at once
            for rid in list(pool._owned):
                pool.release(rid)
            live.clear()
        elif op == "admit":
            rid = rid_next
            rid_next += 1
            toks = [int(t) for t in rng.integers(0, 4,
                                                 int(rng.integers(2, 20)))]
            try:
                pool.acquire_prefix(rid, toks, align=ALIGN)
                pool.alloc_to(rid, len(toks))
            except PoolExhausted:
                pool.release(rid)
                continue
            pool.insert_prefix(rid, toks, states=_states_for(toks))
            live.add(rid)
        assert pool.invariant_ok(), (pool._free, pool._owned)
        _snaps_consistent(pool)
        # double-free probe: releasing an already-released rid is a
        # no-op (the evacuation path and the decode-finish path may
        # race over the same rid)
        pool.release(99999)
        assert pool.invariant_ok()
    for rid in sorted(live):
        pool.release(rid)
    assert pool.invariant_ok()
    _snaps_consistent(pool)
    assert pool.free_blocks + pool.cached_blocks == num_blocks


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 31))
def test_crash_wipe_snapshot_lockstep(seed):
    _wipe_core(seed)


def test_crash_wipe_snapshot_lockstep_seeded():
    """Always-run mirror of the hypothesis property (fixed seeds)."""
    for seed in (0, 1, 7, 23, 1337):
        _wipe_core(seed)
