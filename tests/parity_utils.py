"""Shared warm/cold parity scaffolding for the serving test suites.

One place for the helpers that every parity suite re-derived locally
(test_prefix_reuse, test_bucketed_prefill, test_fused_decode,
test_state_snapshot_reuse): prompt/frame generation, the sequential
1P:1D frontend driver, the bitwise PrefillOutput comparator, and the
decode-admission shim. Keeping them here means the parity CONTRACT is
stated once — a suite that needs a stricter or looser comparison says
so explicitly instead of forking a helper.
"""
import dataclasses

import numpy as np

from conftest import reduced_params
from repro.serving.cluster import ServeRequest
from repro.serving.frontend import ClusterFrontend

# pool geometry shared by the serving parity suites: small blocks force
# multi-block prefixes (and COW tails) even at reduced prompt lengths
POOL_KW = {"block_size": 4, "num_blocks": 96}
BS = POOL_KW["block_size"]

def make_prompts(cfg, rng, lens):
    return [list(map(int, rng.integers(0, cfg.vocab_size, int(n))))
            for n in lens]


def make_frames(cfg, rng, n):
    """Encoder frames for enc-dec configs, else None."""
    if not cfg.is_encoder_decoder:
        return None
    return [np.asarray(rng.normal(size=(cfg.encoder_seq, cfg.d_model)) * 0.1,
                       np.float32) for _ in range(n)]


def family_setup(arch, rng, *, sorted_moe=True):
    """(cfg, params, frames) for one family.

    ``sorted_moe`` swaps capacity dispatch for the dropless sorted
    dispatch (identical param shapes): capacity drops are a function of
    the window population, so suites that reuse prefixes at NON-window
    boundaries need sorted dispatch for exact parity. Window-aligned
    suites (snapshot reuse aligns to lcm(window, chunk, block)) keep
    capacity dispatch and still match bitwise.
    """
    cfg, params = reduced_params(arch)
    if sorted_moe and cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  dispatch="sorted"))
    frames = None
    if cfg.is_encoder_decoder:
        frames = np.asarray(
            rng.normal(size=(cfg.encoder_seq, cfg.d_model)) * 0.1,
            np.float32)
    return cfg, params, frames


def serve_sequential(cfg, params, prompts, *, prefix_cache, frames=None,
                     max_new=3, max_ticks=80, pool_kw=None):
    """Sequential requests through a 1P:1D frontend.

    Returns (generated sequences, frontend) — the prefill node under
    test is ``frontend.groups["default"].prefills[0]``.
    """
    kw = dict(pool_kw or POOL_KW)
    fe = ClusterFrontend(cfg, topology={"default": (1, 1)}, params=params,
                         prefix_cache=prefix_cache,
                         prefill_kwargs=dict(kw), decode_kwargs=dict(kw))
    gens = []
    for i, toks in enumerate(prompts):
        req = ServeRequest(rid=i, tokens=list(toks), max_new_tokens=max_new,
                           frames=frames)
        fe.run([req], max_ticks=max_ticks)
        assert req.done
        gens.append(list(req.generated))
    return gens, fe


def prefill_node(fe, group="default"):
    return fe.groups[group].prefills[0]


def assert_state_equal(a, b, ctx=""):
    """Bitwise equality of two mamba_state / snapshot trees
    ({(blk, sub): {leaf: array}}) — the recurrent-state parity bar."""
    assert set(a) == set(b), (ctx, set(a) ^ set(b))
    for key in sorted(a):
        assert set(a[key]) == set(b[key]), (ctx, key)
        for leaf in a[key]:
            x, y = np.asarray(a[key][leaf]), np.asarray(b[key][leaf])
            assert x.dtype == y.dtype and x.shape == y.shape, \
                (ctx, key, leaf, x.dtype, y.dtype, x.shape, y.shape)
            assert np.array_equal(x, y), \
                (ctx, key, leaf, float(np.abs(x - y).max()))


def outputs_equal(a, b):
    """Bitwise PrefillOutput comparison: tokens, KV, recurrent state,
    cross-attention caches."""
    assert a.first_token == b.first_token
    assert a.prompt_len == b.prompt_len
    if a.k is not None:
        assert np.array_equal(np.asarray(a.k), np.asarray(b.k))
        assert np.array_equal(np.asarray(a.v), np.asarray(b.v))
    assert_state_equal(a.mamba_state or {}, b.mamba_state or {})
    for key in (a.cross or {}):
        assert np.array_equal(np.asarray(a.cross[key][0]),
                              np.asarray(b.cross[key][0]))
        assert np.array_equal(np.asarray(a.cross[key][1]),
                              np.asarray(b.cross[key][1]))


def decode_setup(arch, n_prompts=3, seed=5):
    """(cfg, params, prompts, frames) for the decode-path suites."""
    cfg, params = reduced_params(arch)
    rng = np.random.default_rng(seed)
    prompts = [list(rng.integers(0, cfg.vocab_size, int(n)))
               for n in rng.integers(5, 14, n_prompts)]
    frames = None
    if cfg.is_encoder_decoder:
        frames = [np.asarray(
            rng.normal(size=(cfg.encoder_seq, cfg.d_model)) * 0.1,
            np.float32) for _ in prompts]
    return cfg, params, prompts, frames


def admit(pool, de, rid, out, room=10, bs=BS):
    """Alloc + write + admit one prefill output into a DecodeEngine."""
    pool.alloc(rid, out.prompt_len + room)
    if out.k is not None:
        pool.write_prefill(
            pool.owned(rid)[: (out.prompt_len + bs - 1) // bs],
            out.k, out.v)
    return de.admit(rid, out, pool.owned(rid))
