"""Speculative decoding (paper §6.1): greedy speculation must be LOSSLESS —
token-identical to target-only decoding — while accepting draft tokens."""
import gc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_params
from repro.models.caches import zeros_cache
from repro.models.modeling import forward_decode, forward_prefill
from repro.models.params import init_params
from repro.serving.speculative import SpeculativeDecoder, _pad_cache


@pytest.fixture(autouse=True, scope="module")
def _fresh_compiler_state():
    """This module compiles the big seed-era EAGER decode scan. Deep
    into a full-suite run the XLA CPU compiler segfaults on it under
    the hundreds of live executables the earlier suites accumulated
    (reproducible at the same test; the module alone is fine) — drop
    them first so these compiles start from a clean slate."""
    jax.clear_caches()
    gc.collect()
    yield


def _target_only(cfg, params, prompt, n):
    first, cache = forward_prefill(
        cfg, params, {"tokens": jnp.asarray([prompt], jnp.int32)})
    cache = _pad_cache(cache, len(prompt) + n + 2)
    out = [int(first[0])]
    tok = first
    while len(out) < n:
        tok, cache = forward_decode(cfg, params, cache, tok)
        out.append(int(tok[0]))
    return out


@pytest.mark.parametrize("draft_same", [True, False])
def test_speculative_is_lossless(draft_same):
    cfg, params = reduced_params("granite-3-8b")
    if draft_same:
        d_cfg, d_params = cfg, params          # perfect draft
    else:
        d_cfg = cfg.replace(num_layers=1, name="draft")
        d_params = init_params(d_cfg, jax.random.PRNGKey(99))
    spec = SpeculativeDecoder(cfg, params, d_cfg, d_params, k=3)
    rng = np.random.default_rng(4)
    prompt = list(rng.integers(0, cfg.vocab_size, 9))
    n = 10
    got = spec.generate(prompt, n)
    want = _target_only(cfg, params, prompt, n)
    assert got == want, (got, want)
    if draft_same:
        # a perfect draft should be accepted (near-)always
        assert spec.stats.acceptance > 0.9
    assert spec.stats.proposed > 0
    # the emitted counter is exact even when the final round overshoots
    # max_new_tokens (the truncated tail is subtracted back out)
    assert spec.stats.emitted == n


def test_speculative_saves_target_steps_with_good_draft():
    cfg, params = reduced_params("granite-3-8b")
    spec = SpeculativeDecoder(cfg, params, cfg, params, k=4)
    rng = np.random.default_rng(5)
    prompt = list(rng.integers(0, cfg.vocab_size, 8))
    n = 12
    spec.generate(prompt, n)
    # perfect draft: ~n/(k+1) verification passes instead of n steps
    assert spec.stats.target_steps <= n // 2 + 2


def test_oracle_is_incremental_not_quadratic(monkeypatch):
    """Bugfix regression: the seed-era oracle re-prefilled the FULL
    prefix through both models every round — `_draft_cache_upto` on the
    rollback path (instead of the captured-but-dead `d_snapshot`) and
    `_target_logits_at` over prompt+out+proposal for verification,
    O(n^2) model work over a generation. The fixed oracle prefills each
    model exactly once, verifies teacher-forced through an incremental
    target cache, and rolls the draft back to its snapshot, replaying
    only the accepted suffix through forward_decode."""
    import repro.serving.speculative as sp
    cfg, params = reduced_params("granite-3-8b")
    d_cfg = cfg.replace(num_layers=1, name="draft")
    d_params = init_params(d_cfg, jax.random.PRNGKey(99))
    calls = {"prefill": 0, "decode": 0}
    real_p, real_d = sp.forward_prefill, sp.forward_decode

    def count_p(*a, **kw):
        calls["prefill"] += 1
        return real_p(*a, **kw)

    def count_d(*a, **kw):
        calls["decode"] += 1
        return real_d(*a, **kw)

    monkeypatch.setattr(sp, "forward_prefill", count_p)
    monkeypatch.setattr(sp, "forward_decode", count_d)
    k, n = 3, 10
    spec = SpeculativeDecoder(cfg, params, d_cfg, d_params, k=k)
    rng = np.random.default_rng(4)
    out = spec.generate(list(rng.integers(0, cfg.vocab_size, 9)), n)
    assert len(out) == n
    # one prefill per model, ever — not one per round
    assert calls["prefill"] == 2
    # per round: k draft proposals + k+1 verify positions + the
    # accepted-suffix replay. EXACT accounting — any full-prefix rerun
    # would blow this up.
    rounds = spec.stats.target_steps - 1
    assert calls["decode"] == \
        rounds * (2 * k + 1) + spec.stats.draft_replay_tokens
    assert spec.stats.draft_replay_tokens <= rounds * (k + 1)
    # the quadratic seed-era helpers are gone for good
    assert not hasattr(spec, "_target_logits_at")
    assert not hasattr(spec, "_draft_cache_upto")


def test_spec_stats_count_the_bonus_token_exactly():
    """Bugfix regression: when all k proposals are accepted the target
    emits a FREE bonus token; seed-era SpecStats only tracked
    proposed/accepted, so any tokens-per-step estimate disagreed with
    actual emission. `emitted` now counts every emitted token and
    `tokens_per_step` is exact."""
    cfg, params = reduced_params("granite-3-8b")
    spec = SpeculativeDecoder(cfg, params, cfg, params, k=4)
    rng = np.random.default_rng(5)
    out = spec.generate(list(rng.integers(0, cfg.vocab_size, 8)), 12)
    st = spec.stats
    assert len(out) == 12
    assert st.acceptance == 1.0          # perfect draft
    # prefill emits 1, then 3 all-accepted rounds emit k+1 = 5 each
    # (the 5th is the bonus token); the overshoot past max_new_tokens
    # is subtracted, so emitted == 12 over 4 target passes exactly
    assert st.target_steps == 4
    assert st.emitted == 12
    assert st.tokens_per_step == pytest.approx(12 / 4)
    # accepted alone (12 here) undercounts emission per round — the
    # bonus tokens are only visible through `emitted`
    assert st.proposed == 12 and st.accepted == 12
