"""Speculative decoding (paper §6.1): greedy speculation must be LOSSLESS —
token-identical to target-only decoding — while accepting draft tokens."""
import gc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_params
from repro.models.caches import zeros_cache
from repro.models.modeling import forward_decode, forward_prefill
from repro.models.params import init_params
from repro.serving.speculative import SpeculativeDecoder, _pad_cache


@pytest.fixture(autouse=True, scope="module")
def _fresh_compiler_state():
    """This module compiles the big seed-era EAGER decode scan. Deep
    into a full-suite run the XLA CPU compiler segfaults on it under
    the hundreds of live executables the earlier suites accumulated
    (reproducible at the same test; the module alone is fine) — drop
    them first so these compiles start from a clean slate."""
    jax.clear_caches()
    gc.collect()
    yield


def _target_only(cfg, params, prompt, n):
    first, cache = forward_prefill(
        cfg, params, {"tokens": jnp.asarray([prompt], jnp.int32)})
    cache = _pad_cache(cache, len(prompt) + n + 2)
    out = [int(first[0])]
    tok = first
    while len(out) < n:
        tok, cache = forward_decode(cfg, params, cache, tok)
        out.append(int(tok[0]))
    return out


@pytest.mark.parametrize("draft_same", [True, False])
def test_speculative_is_lossless(draft_same):
    cfg, params = reduced_params("granite-3-8b")
    if draft_same:
        d_cfg, d_params = cfg, params          # perfect draft
    else:
        d_cfg = cfg.replace(num_layers=1, name="draft")
        d_params = init_params(d_cfg, jax.random.PRNGKey(99))
    spec = SpeculativeDecoder(cfg, params, d_cfg, d_params, k=3)
    rng = np.random.default_rng(4)
    prompt = list(rng.integers(0, cfg.vocab_size, 9))
    n = 10
    got = spec.generate(prompt, n)
    want = _target_only(cfg, params, prompt, n)
    assert got == want, (got, want)
    if draft_same:
        # a perfect draft should be accepted (near-)always
        assert spec.stats.acceptance > 0.9
    assert spec.stats.proposed > 0


def test_speculative_saves_target_steps_with_good_draft():
    cfg, params = reduced_params("granite-3-8b")
    spec = SpeculativeDecoder(cfg, params, cfg, params, k=4)
    rng = np.random.default_rng(5)
    prompt = list(rng.integers(0, cfg.vocab_size, 8))
    n = 12
    spec.generate(prompt, n)
    # perfect draft: ~n/(k+1) verification passes instead of n steps
    assert spec.stats.target_steps <= n // 2 + 2
