"""Tickless event-driven serving core: events drain in nondecreasing
virtual time, no admission starvation when a group goes idle
mid-transfer, staged-vs-tickless token parity per family — plus the
tick-era accounting bugfixes (rejections counted per request, true
even-window median, nonzero blocking stall for state-only payloads,
least-loaded routing for unknown scenarios)."""
import dataclasses

import numpy as np
import pytest

from conftest import reduced_params
from repro.core.transfer import LinkModel
from repro.serving.cluster import MiniCluster, ServeRequest
from repro.serving.frontend import ClusterFrontend, _median

# one config per family: dense / MoE / hybrid SSM+attn / encoder-decoder
FAMILIES = ["granite-3-8b", "qwen2-moe-a2.7b", "jamba-1.5-large-398b",
            "whisper-base"]


def _requests(cfg, n, *, scenario="default", seed=3, lo=5, hi=12,
              max_new=4, rid0=0):
    rng = np.random.default_rng(seed)
    return [ServeRequest(
        rid=rid0 + i, scenario=scenario,
        tokens=list(map(int, rng.integers(0, cfg.vocab_size,
                                          int(rng.integers(lo, hi))))),
        max_new_tokens=max_new) for i in range(n)]


# --------------------------------------------------- accounting bugfixes

def test_median_true_even_window():
    """Regression: even-length windows returned the UPPER middle sample,
    biasing Eq.1 inputs and the *_median_s telemetry high."""
    assert _median([]) == 0.0
    assert _median([3.0, 1.0, 2.0]) == 2.0
    assert _median([4.0, 1.0, 2.0, 3.0]) == 2.5   # was 3.0
    assert _median([1.0, 2.0]) == 1.5             # was 2.0


def test_rejections_counted_per_request_not_per_probe():
    """Regression: offer() bumped the §3.5 rejection counter once per
    prefill node probed, inflating forwarding stats by up to n_prefill x
    per rejected request."""
    cfg, params = reduced_params("granite-3-8b")
    fe = ClusterFrontend(cfg, topology={"chat": (2, 1)}, params=params,
                         prefill_kwargs={"batch_size": 1}, tickless=False)
    reqs = _requests(cfg, 4, scenario="chat")
    for r in reqs:
        fe.submit(r)
    fe.tick()
    g = fe.groups["chat"]
    assert sorted(g.accepted) == [0, 1]           # one per node
    # the other two bounced off BOTH nodes: ONE rejection per request,
    # per-node placement probes ledgered separately
    assert g.rejections == 2
    assert g.probe_rejections == 4
    for _ in range(60):
        fe.tick()
        if all(r.done for r in reqs):
            break
    assert all(r.done for r in reqs)


def test_blocking_stall_charged_for_state_only_payload():
    """Regression: blocking admission ledgered stall = 0.0 whenever
    ``out.k is None`` — attn-free (pure SSM) requests whose recurrent
    state still crosses the link never charged D2D wait."""
    cfg, params = reduced_params("mamba2-2.7b")
    mc = MiniCluster(cfg, n_prefill=1, n_decode=1, params=params,
                     overlap_transfer=False)
    req = _requests(cfg, 1, max_new=3)[0]
    mc.run([req], max_ticks=60)
    assert req.done
    g = mc.frontend.groups["default"]
    assert g.n_blocking_admits == 1
    assert g.blocking_waits[-1] > 0.0
    assert g.transfer_stats()["admission_wait_mean_s"] > 0.0


def test_unknown_scenario_routes_to_least_loaded_group():
    """Regression: every unknown scenario used to land on g0 regardless
    of load; an unknown-scenario burst must spread instead of piling
    onto g0 while other groups idle."""
    cfg, params = reduced_params("granite-3-8b")
    fe = ClusterFrontend(cfg, topology={"chat": (1, 1), "summ": (1, 1)},
                         params=params, tickless=False)
    probe = _requests(cfg, 1, scenario="mystery", rid0=90)[0]
    assert fe.group_for(probe) is fe.groups["chat"]     # tie -> g0
    fe.groups["chat"].prefills[0].forming.append(
        _requests(cfg, 1, rid0=91)[0])
    assert fe.group_for(probe) is fe.groups["summ"]     # least-loaded
    fe.groups["chat"].prefills[0].forming.clear()
    burst = _requests(cfg, 2, scenario="mystery", seed=7, rid0=70)
    for r in burst:
        fe.submit(r)
    fe.tick()
    assert fe.groups["chat"].accepted and fe.groups["summ"].accepted


# ------------------------------------------------------- event-queue core

def test_event_drain_nondecreasing_virtual_time():
    """The tickless loop drains every event (batches, hand-offs, link
    segment landings, decode steps) in nondecreasing virtual time, and
    the per-request second-granularity stamps are ordered."""
    cfg, params = reduced_params("granite-3-8b")
    fe = ClusterFrontend(cfg, topology={"default": (2, 2)}, params=params)
    reqs = _requests(cfg, 6, max_new=3)
    fe.run(reqs)
    assert all(r.done for r in reqs)
    g = fe.groups["default"]
    log = g.event_log
    assert len(log) > 10
    assert all(a[0] <= b[0] + 1e-12 for a, b in zip(log, log[1:])), \
        "event drain went back in virtual time"
    assert {"batch", "xfer", "step", "segment"} <= {k for _, k in log}
    for r in reqs:
        assert 0.0 <= r.submit_t <= r.first_token_t <= r.finish_t
    assert len(g.ttft_s) == len(reqs)
    assert all(t >= 0.0 for t in g.ttft_s)


def test_no_admission_starvation_when_group_idle_mid_transfer():
    """The case the old frontend spinning-ticks hack papered over: a
    slow link leaves the transfer in flight after prefill finishes with
    the group otherwise idle (nothing forming, decode empty). The event
    loop must advance through the link landings and admit — no
    starvation, and the wire wait shows up in the admission ledger."""
    cfg, params = reduced_params("granite-3-8b")
    link = LinkModel(bandwidth=1e6, c_ctrl=1e-3)   # wire time dominates
    mc = MiniCluster(cfg, n_prefill=1, n_decode=1, params=params,
                     link=link, overlap_transfer=True)
    req = _requests(cfg, 1, lo=11, hi=12, max_new=3)[0]
    mc.run([req], max_ticks=40)
    assert req.done
    g = mc.frontend.groups["default"]
    assert g.sched.idle() and g.sched.n_admitted == 1
    assert g.sched.admission_waits[-1] > 0.0


@pytest.mark.parametrize("arch", FAMILIES)
def test_staged_vs_tickless_token_parity(arch):
    """Lockstep pin: the tickless event loop is token-identical to the
    staged tick shim per family (greedy decode is
    scheduling-order-invariant). The repeated first prompt exercises the
    warm prefix-reuse path through both schedulers."""
    rng = np.random.default_rng(11)
    cfg, params = reduced_params(arch)
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  dispatch="sorted"))
    frames = None
    if cfg.is_encoder_decoder:
        frames = np.asarray(
            rng.normal(size=(cfg.encoder_seq, cfg.d_model)) * 0.1,
            np.float32)
    base = list(map(int, rng.integers(0, cfg.vocab_size, 11)))
    prompts = [base,
               list(map(int, rng.integers(0, cfg.vocab_size, 7))),
               base + list(map(int, rng.integers(0, cfg.vocab_size, 4)))]
    gens = {}
    for tickless in (True, False):
        mc = MiniCluster(cfg, n_prefill=1, n_decode=2, params=params,
                         overlap_transfer=True, tickless=tickless)
        outs = []
        for i, toks in enumerate(prompts):
            req = ServeRequest(rid=i, tokens=list(toks), max_new_tokens=3,
                               frames=frames)
            mc.run([req], max_ticks=80)
            assert req.done, (arch, tickless, i)
            outs.append(list(req.generated))
        gens[tickless] = outs
    assert gens[True] == gens[False], arch
