"""Scenario-aware multi-group frontend: affinity routing, cross-group
fallback, runtime P/D role flips (MetaStore-visible, in-flight work
completes), and token parity with the single-group MiniCluster shim."""
import numpy as np
import pytest

from conftest import reduced_params
from repro.core.perf_model import InstanceProfile, optimal_ratio
from repro.serving.cluster import MiniCluster, ServeRequest
from repro.serving.frontend import ClusterFrontend, RatioAdjuster


def _requests(cfg, n, *, scenario="default", seed=3, lo=5, hi=12,
              max_new=4, rid0=0):
    rng = np.random.default_rng(seed)
    return [ServeRequest(
        rid=rid0 + i, scenario=scenario,
        tokens=list(rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(lo, hi)))),
        max_new_tokens=max_new) for i in range(n)]


def test_scenario_affinity_routing():
    """With capacity available everywhere, requests land in their own
    scenario's group — never a foreign one."""
    cfg, params = reduced_params("granite-3-8b")
    fe = ClusterFrontend(cfg, topology={"chat": (1, 1), "summ": (1, 1)},
                         params=params)
    reqs = (_requests(cfg, 3, scenario="chat", seed=3)
            + _requests(cfg, 3, scenario="summ", seed=4, rid0=10))
    fe.run(reqs, max_ticks=80)
    assert all(r.done for r in reqs)
    assert sorted(fe.groups["chat"].accepted) == [0, 1, 2]
    assert sorted(fe.groups["summ"].accepted) == [10, 11, 12]
    # both groups are registered and populated in the MetaStore
    assert fe.meta.group_scenario == {"g0": "chat", "g1": "summ"}
    assert fe.meta.group_members("g0", "P") == ["g0/P0"]
    assert fe.meta.group_members("g1", "D") == ["g1/D0"]


def test_cross_group_fallback_when_home_saturated():
    """§3.5: a request rejected everywhere in its home group is forwarded
    to another scenario's group; with that one full too, it waits at the
    gateway."""
    cfg, params = reduced_params("granite-3-8b")
    fe = ClusterFrontend(cfg, topology={"chat": (1, 1), "summ": (1, 1)},
                         params=params,
                         prefill_kwargs={"batch_size": 1})
    reqs = _requests(cfg, 3, scenario="chat", seed=5)
    for r in reqs:
        fe.submit(r)
    fe.tick()
    assert fe.groups["chat"].accepted == [0]     # home takes the first
    assert fe.groups["summ"].accepted == [1]     # overflow forwarded
    assert [r.rid for r in fe.pending] == [2]    # third waits (gateway)
    assert fe.rejections >= 2
    for _ in range(60):
        fe.tick()
        if all(r.done for r in reqs):
            break
    assert all(r.done for r in reqs)


def test_role_flip_updates_metastore_and_inflight_completes():
    """A draining decode finishes its in-flight request before the flip;
    the role change then shows up in the MetaStore and the flipped-in
    prefill node serves new traffic."""
    cfg, params = reduced_params("granite-3-8b")
    fe = ClusterFrontend(cfg, topology={"default": (1, 2)}, params=params)
    g = fe.groups["default"]
    req = _requests(cfg, 1, max_new=5)[0]
    fe.submit(req)
    fe.tick()   # prefill
    fe.tick()   # transfer + first decode step
    busy = next(d for d in g.decodes if d.requests)
    busy.draining = True
    assert not g.flips
    for _ in range(30):
        fe.tick()
        if req.done:
            break
    assert req.done and len(req.generated) == 6   # in-flight completed
    for _ in range(3):
        fe.tick()
    assert [f for f in g.flips if f[1] == busy.iid and f[3] == "D->P"]
    assert busy.iid not in fe.meta.instances          # old role removed
    assert g.ratio == (2, 1)
    new_iid = g.flips[-1][2]
    assert new_iid in fe.meta.group_members("g0", "P")  # re-registered
    # the flipped-in prefill serves real traffic over the same params
    more = _requests(cfg, 2, seed=9, rid0=50)
    fe.run(more, max_ticks=60)
    assert all(r.done for r in more)


def test_adjuster_flips_toward_profile_optimum():
    """Deployed 3P:1D with a decode-heavy Eq.1 profile: the adjuster
    drains and flips prefills one at a time until the optimum ratio."""
    cfg, params = reduced_params("granite-3-8b")
    prof = InstanceProfile(ttft_bs=0.1, b_p=4, r_pre=1.0, tpot_bs=0.05,
                           b_d=8, gen_tokens=100.0, xi=0.0)
    assert optimal_ratio(prof, 4) == (1, 3)
    fe = ClusterFrontend(cfg, topology={"default": (3, 1)}, params=params,
                         adjust_ratio=True, adjust_interval=1,
                         profiles={"default": prof})
    g = fe.groups["default"]
    for _ in range(6):
        fe.tick()
    assert g.ratio == (1, 3)
    assert [f[3] for f in g.flips] == ["P->D", "P->D"]
    assert len(fe.meta.group_members("g0", "P")) == 1
    assert len(fe.meta.group_members("g0", "D")) == 3


def test_adjuster_never_violates_min_each():
    cfg, params = reduced_params("granite-3-8b")
    prof = InstanceProfile(ttft_bs=0.1, b_p=4, r_pre=1.0, tpot_bs=0.05,
                           b_d=8, gen_tokens=100.0, xi=0.0)
    fe = ClusterFrontend(cfg, topology={"default": (1, 1)}, params=params,
                         adjust_ratio=True, adjust_interval=1,
                         profiles={"default": prof})
    for _ in range(4):
        fe.tick()
    assert fe.groups["default"].ratio == (1, 1)   # nothing to give up


def test_adjuster_admission_wait_spike_shifts_ratio():
    """Prefilled KV queueing for decode slots (the transfer pipeline's
    admission-wait ledger) is decode starvation the queue/TTFT pressure
    cannot see: a spike must arm and then fire a P->D flip."""
    cfg, params = reduced_params("granite-3-8b")
    fe = ClusterFrontend(cfg, topology={"default": (2, 1)}, params=params,
                         adjust_ratio=True)
    g = fe.groups["default"]
    adj = fe.adjusters["default"]
    # flat wait history: no vote, no flip
    g.sched.admission_waits = [1e-6] * 16
    g.sched.n_admitted = 16
    assert adj.maybe_adjust(8) is None
    assert adj.maybe_adjust(16) is None
    assert not adj.wait_votes and not g.draining_nodes()
    # recent waits spike an order of magnitude over the earlier window
    g.sched.admission_waits = [1e-6] * 12 + [1e-3] * 4
    g.sched.n_admitted = 20
    assert adj.maybe_adjust(24) is None          # armed (hysteresis)
    g.sched.admission_waits += [1e-3] * 2        # spike persists
    g.sched.n_admitted = 22
    assert adj.maybe_adjust(32) == "P->D"        # confirmed -> flip
    assert adj.wait_votes == [24, 32]
    assert g.draining_nodes()                    # a prefill is draining
    assert adj.decisions[-1][1] == "P->D"


def test_adjuster_wait_vote_expires_without_fresh_samples():
    """A historical burst must not keep voting on a quiet group: with no
    new admissions since the last adjust tick the signal expires."""
    cfg, params = reduced_params("granite-3-8b")
    fe = ClusterFrontend(cfg, topology={"default": (2, 1)}, params=params,
                         adjust_ratio=True)
    g = fe.groups["default"]
    adj = fe.adjusters["default"]
    g.sched.admission_waits = [1e-6] * 12 + [1e-3] * 4
    g.sched.n_admitted = 16
    assert adj.maybe_adjust(8) is None           # armed on fresh spike
    assert adj.wait_votes == [8]
    # traffic goes quiet: same ledger, no new admissions -> vote gone
    assert adj.maybe_adjust(16) is None
    assert adj.maybe_adjust(24) is None
    assert adj.wait_votes == [8]
    assert not g.draining_nodes()


def test_adjuster_wait_flip_not_immediately_reverted():
    """At the Eq.1 optimum a wait-driven P->D flip relieves the spike;
    Eq.1 then wants the node back. The cooldown must hold the revert
    (two drains per round trip would oscillate forever), then allow it
    once the extra decode has had time to prove itself."""
    cfg, params = reduced_params("granite-3-8b")
    prof = InstanceProfile(ttft_bs=0.2, b_p=4, r_pre=1.0, tpot_bs=0.01,
                           b_d=8, gen_tokens=20.0, xi=0.0)
    assert optimal_ratio(prof, 3) == (2, 1)      # deployed == optimum
    fe = ClusterFrontend(cfg, topology={"default": (2, 1)}, params=params,
                         adjust_ratio=True, profiles={"default": prof})
    g = fe.groups["default"]
    adj = fe.adjusters["default"]
    g.sched.admission_waits = [1e-6] * 12 + [1e-3] * 4
    g.sched.n_admitted = 16
    assert adj.maybe_adjust(8) is None           # Eq.1 tie; spike arms
    g.sched.admission_waits += [1e-3] * 2
    g.sched.n_admitted = 18
    assert adj.maybe_adjust(16) == "P->D"        # wait-driven flip
    g.tick(17)                                   # idle node drain completes
    assert g.ratio == (1, 2)
    # Eq.1 now wants D->P, but the cooldown (4 intervals) holds it
    for t in (24, 32, 40):
        assert adj.maybe_adjust(t) is None
        assert g.ratio == (1, 2)
    # cooldown over: the correction may arm and fire again
    assert adj.maybe_adjust(48) is None          # arms
    assert adj.maybe_adjust(56) == "D->P"


def test_multi_group_outputs_match_single_group_baseline():
    """Acceptance: streamed outputs from >= 2 concurrent scenario groups
    are identical to the single-group MiniCluster baseline for a fixed
    seed (greedy decode is routing-invariant)."""
    cfg, params = reduced_params("granite-3-8b")

    def fresh(rid0=0):
        return (_requests(cfg, 3, scenario="chat", seed=11)
                + _requests(cfg, 3, scenario="summ", seed=12, rid0=10))

    streams: dict = {}
    multi = fresh()
    for r in multi:
        r.on_token = streams.setdefault(r.rid, []).append
    fe = ClusterFrontend(cfg, topology={"chat": (1, 1), "summ": (1, 1)},
                         params=params)
    fe.run(multi, max_ticks=80)
    base = fresh()
    mc = MiniCluster(cfg, n_prefill=2, n_decode=2, params=params)
    mc.run(base, max_ticks=80)
    assert all(r.done for r in multi) and all(r.done for r in base)
    for m, b in zip(multi, base):
        assert m.generated == b.generated, m.rid
        assert streams[m.rid] == m.generated      # SSE order preserved
