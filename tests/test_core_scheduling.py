"""Gateway / scheduler / perf-model tests: Eq.1-2 properties, on-demand
forwarding invariants, simulator behavior under overload."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.cluster_sim import ClusterSim, SimConfig, run_workload
from repro.core.perf_model import (InstanceProfile, continuous_ratio,
                                   mismatch, optimal_ratio, throughput)
from repro.core.profiles import profile_for
from repro.core.requests import WorkloadGenerator


profiles = st.builds(
    InstanceProfile,
    ttft_bs=st.floats(0.05, 2.0),
    b_p=st.integers(1, 16),
    r_pre=st.floats(0.2, 1.0),
    tpot_bs=st.floats(0.005, 0.1),
    b_d=st.integers(4, 64),
    gen_tokens=st.floats(8, 512),
    xi=st.floats(0.0, 0.1),
)


@settings(max_examples=60, deadline=None)
@given(p=profiles, total=st.integers(4, 64))
def test_optimal_ratio_is_argmax(p, total):
    n_p, n_d = optimal_ratio(p, total)
    assert n_p + n_d == total and n_p >= 1 and n_d >= 1
    phi = throughput(p, n_p, n_d)
    for dp in (-1, 1):
        np2, nd2 = n_p + dp, n_d - dp
        if np2 >= 1 and nd2 >= 1:
            assert phi >= throughput(p, np2, nd2) - 1e-12


@settings(max_examples=60, deadline=None)
@given(p=profiles, total=st.integers(8, 64))
def test_optimal_ratio_tracks_continuous_eq1(p, total):
    """Integer optimum stays near the closed-form Eq.1 ratio."""
    n_p, n_d = optimal_ratio(p, total)
    r = continuous_ratio(p)
    n_p_cont = total * r / (1 + r)
    assert abs(n_p - n_p_cont) <= 2.0 + 0.25 * total


@settings(max_examples=40, deadline=None)
@given(p=profiles, total=st.integers(6, 40))
def test_mismatch_argmin_is_near_phi_argmax(p, total):
    """Eq.1: in the continuous relaxation, minimizing the capability
    mismatch IS maximizing Phi; with integers the two argmaxes can differ
    by at most a couple of instances (granularity), and the min-mismatch
    ratio must retain most of the optimal throughput."""
    n_p, n_d = optimal_ratio(p, total)
    mis = {a: mismatch(p, a, total - a) for a in range(1, total)}
    a_min = min(mis, key=mis.get)
    assert abs(a_min - n_p) <= 2, (a_min, n_p)
    phi_opt = throughput(p, n_p, n_d)
    phi_min_mis = throughput(p, a_min, total - a_min)
    assert phi_min_mis >= 0.5 * phi_opt


# --------------------------------------------------------------- sim
def _mk_sim(policy, *, n_p=2, n_d=4, seed=0, **kw):
    prof = profile_for(get_config("pangu-38b"))
    cfg = SimConfig(profile=prof, **kw)
    return ClusterSim(cfg, n_prefill=n_p, n_decode=n_d, policy=policy,
                      seed=seed)


def test_requests_never_assigned_to_busy_prefill():
    """On-demand invariant (Eq. 2): every acceptance happened while the
    instance had a free seat — rejections forced gateway waiting instead."""
    sim = _mk_sim("ondemand")
    gen = WorkloadGenerator(base_rps=60, seed=3)
    reqs = gen.arrivals(30.0)
    # wrap offer to check the invariant at accept time
    orig_offer = type(sim.prefills[0]).offer
    violations = []

    def checked(self, req):
        idle_before = self.idle()
        ok = orig_offer(self, req)
        if ok and not idle_before:
            violations.append(req.rid)
        return ok

    type(sim.prefills[0]).offer = checked
    try:
        run_workload(sim, reqs, 40.0)
    finally:
        type(sim.prefills[0]).offer = orig_offer
    assert not violations


def test_ondemand_beats_baseline_under_overload():
    """Fig. 14a: with heavy load, removing local queues + gateway retries
    holds success rate far above the queue-status baseline."""
    results = {}
    for policy in ("ondemand", "baseline"):
        gen = WorkloadGenerator(base_rps=80, seed=5)
        reqs = gen.arrivals(40.0)
        sim = _mk_sim(policy, n_p=2, n_d=6, seed=1)
        results[policy] = run_workload(sim, reqs, 60.0)
    assert results["ondemand"]["success_rate"] >= \
        results["baseline"]["success_rate"]
    # overload must actually bite in the baseline for the test to mean much
    assert results["baseline"]["success_rate"] < 0.97


def test_success_degrades_gracefully_with_load():
    rates = []
    for rps in (10, 40, 120):
        gen = WorkloadGenerator(base_rps=rps, seed=7)
        reqs = gen.arrivals(30.0)
        sim = _mk_sim("ondemand", n_p=2, n_d=4, seed=2)
        m = run_workload(sim, reqs, 45.0)
        rates.append(m["success_rate"])
    assert rates[0] >= rates[-1]


def test_timeout_requests_are_counted_once():
    gen = WorkloadGenerator(base_rps=150, seed=9)
    reqs = gen.arrivals(20.0)
    sim = _mk_sim("ondemand", n_p=1, n_d=2, seed=3)
    m = run_workload(sim, reqs, 40.0)
    rids = [r.rid for r in sim.completed] + [r.rid for r in sim.failed]
    assert len(rids) == len(set(rids))


def test_block_free_reduces_d2d_time_in_sim():
    out = {}
    for mode in ("block_free", "block_fixed"):
        gen = WorkloadGenerator(base_rps=20, seed=11)
        reqs = gen.arrivals(30.0)
        sim = _mk_sim("ondemand", n_p=2, n_d=4, seed=4,
                      transfer_mode=mode)
        out[mode] = run_workload(sim, reqs, 45.0)["d2d_mean"]
    assert out["block_free"] < out["block_fixed"]
    reduction = 1 - out["block_free"] / out["block_fixed"]
    assert reduction > 0.25, f"only {reduction:.0%} D2D reduction"
