"""Fused jitted decode step + bucketed prefill (the hot-loop rework).

The fused path must be TOKEN-IDENTICAL to the eager per-layer loop for
every family — including slot churn (admit/evict mid-stream) and warm
prefix-reuse admissions — while doing exactly one pool-storage swap per
step with the old buffer donated, and retracing only when a shape
bucket changes.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import reduced_params
from parity_utils import BS, admit as _admit, decode_setup as _setup
from repro.models.modeling import decode_step_cache_size, forward_decode, \
    forward_prefill
from repro.serving.engine import DecodeEngine, PrefillEngine, \
    prefill_compile_count
from repro.serving.kvcache import PagedKVPool

FAMILIES = ["granite-3-8b", "qwen2-moe-a2.7b", "mamba2-2.7b",
            "jamba-1.5-large-398b", "pixtral-12b", "whisper-base"]


def _churn_run(cfg, params, outs, *, fused, num_blocks=48):
    """Admit 0..1, decode, admit 2 mid-stream, evict 0, keep going —
    returns {rid: generated tokens} under a fixed churn schedule."""
    pool = PagedKVPool(cfg, num_blocks=num_blocks, block_size=BS)
    de = DecodeEngine(cfg, params, pool, max_slots=3, fused=fused)
    assert de.fused is fused
    gen = {rid: [out.first_token] for rid, out in enumerate(outs)}

    def steps(n):
        for _ in range(n):
            for slot, tok in de.step().items():
                gen[de.rid[slot]].append(tok)

    slot0 = _admit(pool, de, 0, outs[0])
    _admit(pool, de, 1, outs[1])
    steps(3)
    _admit(pool, de, 2, outs[2])          # admitted mid-flight
    steps(2)
    de.evict(slot0)                       # rid 0 leaves, others continue
    pool.release(0)
    steps(3)
    return gen


@pytest.mark.parametrize("arch", FAMILIES)
def test_fused_matches_eager_with_slot_churn(arch):
    cfg, params, prompts, frames = _setup(arch)
    pe = PrefillEngine(cfg, params)
    outs = pe.run(prompts, frames=frames)
    eager = _churn_run(cfg, params, outs, fused=False)
    fused = _churn_run(cfg, params, outs, fused=True)
    assert fused == eager, arch


def test_fused_matches_lockstep_oracle():
    """Anchor fused-vs-eager agreement to ground truth on one family."""
    cfg, params, prompts, _ = _setup("granite-3-8b")
    pe = PrefillEngine(cfg, params)
    outs = pe.run(prompts)
    pool = PagedKVPool(cfg, num_blocks=48, block_size=BS)
    de = DecodeEngine(cfg, params, pool, max_slots=4, fused=True)
    gen = {}
    for rid, out in enumerate(outs):
        _admit(pool, de, rid, out)
        gen[rid] = [out.first_token]
    for _ in range(4):
        for slot, tok in de.step().items():
            gen[de.rid[slot]].append(tok)
    for rid, toks in enumerate(prompts):
        batch = {"tokens": jnp.asarray([toks], jnp.int32)}
        first, cache = forward_prefill(cfg, params, batch)

        def pad(path, x):
            nm = path[-1].key if hasattr(path[-1], "key") else ""
            if nm in ("k", "v") and x.ndim == 4:
                return jnp.pad(x, ((0, 0), (0, 0), (0, 6), (0, 0)))
            return x
        cache = {"layers": jax.tree_util.tree_map_with_path(
            pad, cache["layers"]), "pos": cache["pos"]}
        seq, tok = [int(first[0])], first
        for _ in range(4):
            tok, cache = forward_decode(cfg, params, cache, tok)
            seq.append(int(tok[0]))
        assert gen[rid] == seq, rid


def test_fused_matches_eager_on_warm_prefix_admission():
    """A suffix-only (prefix-reuse) prefill feeds both decode paths the
    same stitched KV; the generated streams must agree."""
    cfg, params, _, _ = _setup("granite-3-8b")
    rng = np.random.default_rng(11)
    prefix = list(map(int, rng.integers(0, cfg.vocab_size, 8)))
    suffix = list(map(int, rng.integers(0, cfg.vocab_size, 5)))
    pe = PrefillEngine(cfg, params)
    cold, = pe.run([prefix + suffix])
    plen = 8
    prefix_kv = jnp.concatenate(
        [cold.k[:, :plen], cold.v[:, :plen]], axis=-1)
    warm = pe.run_suffix(suffix, prefix_kv)
    assert warm.first_token == cold.first_token
    gens = {}
    for fused in (False, True):
        pool = PagedKVPool(cfg, num_blocks=48, block_size=BS)
        de = DecodeEngine(cfg, params, pool, max_slots=2, fused=fused)
        _admit(pool, de, 0, warm)
        gen = [warm.first_token]
        for _ in range(5):
            gen.append(de.step()[0])
        gens[fused] = gen
    assert gens[True] == gens[False]


def test_fused_step_donates_pool_and_swaps_once():
    """The donation/aliasing contract: the fused step consumes the old
    pool buffer (donated into the jitted program, so XLA updates it in
    place) and the engine swaps storage exactly ONCE per iteration; the
    eager loop pays one swap — a full pool copy — per attention layer
    per step."""
    cfg, params, prompts, _ = _setup("granite-3-8b")
    pe = PrefillEngine(cfg, params)
    outs = pe.run(prompts[:2])
    for fused in (True, False):
        pool = PagedKVPool(cfg, num_blocks=48, block_size=BS)
        de = DecodeEngine(cfg, params, pool, max_slots=2, fused=fused)
        for rid, out in enumerate(outs):
            _admit(pool, de, rid, out)
        base = pool.storage_writes
        old = pool.storage
        de.step()
        writes = pool.storage_writes - base
        if fused:
            assert writes == 1
            assert old.is_deleted()          # donated, not copied
        else:
            assert writes == len(pe.layer_fractions())  # per attn layer
            assert not old.is_deleted()


def test_decode_retraces_bounded_by_table_bucket():
    """Steady-state churn inside one block-table bucket must reuse a
    single compiled fused step; crossing the bucket adds exactly one."""
    cfg, params, prompts, _ = _setup("granite-3-8b")
    pe = PrefillEngine(cfg, params)
    outs = pe.run(prompts)
    # unique pool geometry -> unique jit cache keys for this test
    pool = PagedKVPool(cfg, num_blocks=40, block_size=BS)
    de = DecodeEngine(cfg, params, pool, max_slots=3, fused=True)
    base = decode_step_cache_size()
    slot = _admit(pool, de, 0, outs[0])
    de.step()
    de.evict(slot)
    pool.release(0)
    _admit(pool, de, 1, outs[1])          # same bucket: no retrace
    de.step()
    de.step()
    assert decode_step_cache_size() - base == 1
    # a request spanning more blocks bumps the pow2 table bucket: +1
    long_prompt = list(np.random.default_rng(0).integers(
        0, cfg.vocab_size, 30))
    out_long, = pe.run([long_prompt])
    _admit(pool, de, 2, out_long, room=40)
    de.step()
    assert decode_step_cache_size() - base == 2


def test_prefill_retraces_bounded_by_buckets():
    """Ragged prompt lengths must compile O(num_buckets) prefill
    programs, not O(distinct lengths)."""
    cfg, params, _, _ = _setup("granite-3-8b")
    pe = PrefillEngine(cfg, params)
    assert pe.bucket_prefill
    rng = np.random.default_rng(2)
    lengths = list(range(5, 29))          # 24 distinct ragged lengths
    rng.shuffle(lengths)
    base = prefill_compile_count()
    shapes = set()
    for i in range(0, len(lengths), 4):
        batch = [list(rng.integers(0, cfg.vocab_size, n))
                 for n in lengths[i:i + 4]]
        groups = {}
        for t in batch:
            groups.setdefault(pe._bucket_len(len(t)), []).append(t)
        shapes |= {(len(g), b) for b, g in groups.items()}
        pe.run(batch)
    delta = prefill_compile_count() - base
    assert delta <= len(shapes) <= 8      # buckets {16, 32} x batch sizes
    assert delta < len(set(lengths))      # strictly beats per-length


def test_bucketed_prefill_is_exact():
    """Bucket padding must be inert: identical outputs (tokens AND the
    KV written for real positions) vs exact-length prefill."""
    cfg, params, prompts, _ = _setup("granite-3-8b", n_prompts=4)
    exact = PrefillEngine(cfg, params, bucket_prefill=False)
    bucketed = PrefillEngine(cfg, params, bucket_prefill=True)
    o_e = exact.run(prompts)
    o_b = bucketed.run(prompts)
    for a, b in zip(o_e, o_b):
        assert a.first_token == b.first_token
        assert np.array_equal(np.asarray(a.k), np.asarray(b.k))
        assert np.array_equal(np.asarray(a.v), np.asarray(b.v))
    # the accounting stays exact: padding is tracked separately
    total = sum(len(p) for p in prompts)
    assert exact.compute_tokens == bucketed.compute_tokens == total
    assert exact.padded_tokens < bucketed.padded_tokens


def test_bucketing_universal_and_hatch_retired(monkeypatch):
    """Every family takes the bucketed path by default (the forward is
    pad-invariant by contract — there is no supports_bucketing gate
    anymore). The one-release REPRO_PREFILL=exact env hatch is retired:
    the environment is ignored and exact-length grouping is reachable
    only through the explicit ``bucket_prefill=False`` constructor
    arg."""
    for arch in ("mamba2-2.7b", "jamba-1.5-large-398b", "qwen2-moe-a2.7b",
                 "granite-3-8b"):
        cfg, params = reduced_params(arch)
        assert PrefillEngine(cfg, params).bucket_prefill, arch
        assert not hasattr(PrefillEngine(cfg, params), "supports_bucketing")
    cfg, params = reduced_params("granite-3-8b")
    assert not PrefillEngine(cfg, params, bucket_prefill=False).bucket_prefill
    # the retired env spelling is inert
    monkeypatch.setenv("REPRO_PREFILL", "exact")
    assert PrefillEngine(cfg, params).bucket_prefill
