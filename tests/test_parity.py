"""Prefill->decode incremental parity vs full-sequence forward, per family.

This is the system's central numerical invariant: the P instance's cache,
transferred and decoded on the D side, must continue the sequence exactly
as a monolithic forward would.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import ALL_ARCHS, reduced_params
from repro.models.modeling import (forward_decode, forward_prefill,
                                   forward_seq, lm_logits)


def pad_cache(cache, new_s):
    def f(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("k", "v") and x.ndim == 4:
            return jnp.pad(x, ((0, 0), (0, 0), (0, new_s - x.shape[2]),
                               (0, 0)))
        return x
    return {"layers": jax.tree_util.tree_map_with_path(f, cache["layers"]),
            "pos": cache["pos"]}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_incremental_matches_full(arch):
    cfg, params = reduced_params(arch)
    key = jax.random.PRNGKey(11)
    b, s, extra = 2, 16, 4
    toks = jax.random.randint(key, (b, s + extra), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :s]}
    if cfg.frontend == "vision":
        emb = jax.random.normal(key, (b, s + extra, cfg.d_model)) * 0.1
        batch = {"embeds": emb[:, :s]}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model)) * 0.1

    if cfg.frontend == "vision":
        pytest.skip("vlm decode consumes token ids; covered via smoke")

    _, cache = forward_prefill(cfg, params, batch)
    cache = pad_cache(cache, s + extra)
    nxt = None
    for i in range(extra):
        nxt, cache = forward_decode(cfg, params, cache, toks[:, s + i])

    full = dict(batch, tokens=toks)
    h, _, _ = forward_seq(cfg, params, full, collect_cache=False,
                          remat=False)
    want = jnp.argmax(lm_logits(cfg, params, h[:, -1]), -1)
    np.testing.assert_array_equal(np.asarray(nxt), np.asarray(want))


@pytest.mark.parametrize("arch", ["granite-3-8b", "mistral-nemo-12b"])
def test_windowed_decode_runs(arch):
    """Ring-buffer sliding-window decode (long_500k variant) stays finite
    and wraps correctly past the window boundary."""
    from repro.models.caches import zeros_cache
    cfg, params = reduced_params(arch)
    W = 8
    cache = zeros_cache(cfg, 2, 64, window=W)
    tok = jnp.zeros((2,), jnp.int32)
    for i in range(2 * W + 3):   # cross the wrap twice
        tok, cache = forward_decode(cfg, params, cache, tok, window=W)
        assert bool(jnp.all((tok >= 0) & (tok < cfg.vocab_size)))
    assert int(cache["pos"]) == 2 * W + 3


def test_windowed_equals_full_within_window():
    """While pos < window, windowed decode must equal full decode."""
    from repro.models.caches import zeros_cache
    cfg, params = reduced_params("granite-3-8b")
    W = 16
    c_win = zeros_cache(cfg, 1, W, window=W)
    c_full = zeros_cache(cfg, 1, W)
    t1 = t2 = jnp.asarray([5], jnp.int32)
    for _ in range(W - 1):
        t1, c_win = forward_decode(cfg, params, c_win, t1, window=W)
        t2, c_full = forward_decode(cfg, params, c_full, t2)
        np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
