"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
REDUCED same-family variant, runs one forward/train step on CPU with shape
and finiteness assertions. Full configs are exercised via the dry-run only.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import ALL_ARCHS, reduced_params
from repro.configs import get_config
from repro.models.caches import zeros_cache
from repro.models.modeling import forward_decode, forward_prefill, forward_train
from repro.models.params import param_count_actual
from repro.models.steps import make_train_step
from repro.training.optimizer import adamw_init


def _batch(cfg, b=2, s=32, key=jax.random.PRNGKey(3)):
    batch = {}
    if cfg.frontend == "vision":
        batch["embeds"] = jax.random.normal(key, (b, s, cfg.d_model)) * 0.1
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model)) * 0.1
    batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_loss(arch):
    cfg, params = reduced_params(arch)
    batch = _batch(cfg)
    loss, metrics = forward_train(cfg, params, batch, remat=False)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_updates_params(arch):
    cfg, params = reduced_params(arch)
    batch = _batch(cfg)
    step = make_train_step(cfg, remat=True)
    opt = adamw_init(params)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_opt["step"]) == 1
    # at least one leaf moved and everything stayed finite
    moved = jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), params, new_params)
    assert any(jax.tree.leaves(moved)), f"{arch}: no parameter moved"
    assert all(bool(jnp.all(jnp.isfinite(x)))
               for x in jax.tree.leaves(new_params))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_shapes(arch):
    cfg, params = reduced_params(arch)
    b, s = 2, 32
    batch = {k: v for k, v in _batch(cfg, b, s).items() if k != "labels"}
    first, cache = forward_prefill(cfg, params, batch)
    assert first.shape == (b,)
    assert first.dtype == jnp.int32
    assert int(cache["pos"]) == s
    for leaf in jax.tree.leaves(cache["layers"]):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_step(arch):
    cfg, params = reduced_params(arch)
    b = 2
    cache = zeros_cache(cfg, b, 48)
    tok = jnp.zeros((b,), jnp.int32)
    nxt, cache = forward_decode(cfg, params, cache, tok)
    assert nxt.shape == (b,)
    assert int(cache["pos"]) == 1
    nxt2, cache = forward_decode(cfg, params, cache, nxt)
    assert int(cache["pos"]) == 2
    assert bool(jnp.all((nxt2 >= 0) & (nxt2 < cfg.vocab_size)))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_count_formula_close(arch):
    """The analytic 6ND param formula tracks the real tree within 2%."""
    cfg = get_config(arch).reduced()
    approx = cfg.param_count()
    actual = param_count_actual(cfg)
    assert abs(approx - actual) / actual < 0.02, (approx, actual)


def test_full_configs_match_assignment():
    """Spot-check the exact assigned hyperparameters."""
    c = get_config("qwen1.5-110b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (80, 8192, 64, 8, 49152, 152064)
    assert c.qkv_bias
    c = get_config("qwen2-moe-a2.7b")
    assert (c.moe.num_experts, c.moe.top_k, c.moe.num_shared_experts) == \
        (60, 4, 4)
    c = get_config("deepseek-moe-16b")
    assert (c.moe.num_experts, c.moe.top_k, c.moe.num_shared_experts) == \
        (64, 6, 2)
    c = get_config("jamba-1.5-large-398b")
    assert c.layer_block.count("mamba") == 7 and \
        c.layer_block.count("attn") == 1
    assert (c.moe.num_experts, c.moe.top_k) == (16, 2)
    c = get_config("mamba2-2.7b")
    assert c.attn_free and c.ssm.d_state == 128 and \
        (c.ssm.expand * c.d_model) // c.ssm.head_dim == 80
    c = get_config("whisper-base")
    assert c.encoder_layers == 6 and c.num_layers == 6 and c.d_model == 512
    c = get_config("minicpm-2b")
    assert (c.num_heads, c.num_kv_heads) == (36, 36)


def test_sorted_dispatch_train_step():
    """Dropless MoE dispatch trains end to end (grad path through
    argsort + ragged_dot)."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.models.params import init_params
    from repro.training.optimizer import adamw_init
    cfg = get_config("deepseek-moe-16b").reduced()
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch="sorted"))
    params = init_params(cfg, jax.random.PRNGKey(5))
    batch = _batch(cfg)
    step = make_train_step(cfg, remat=True)
    new_params, new_opt, metrics = jax.jit(step)(
        params, adamw_init(params), batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    moved = jax.tree.map(lambda a, b: bool(jnp.any(a != b)),
                         params, new_params)
    assert any(jax.tree.leaves(moved))
