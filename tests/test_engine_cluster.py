"""Real-compute engine + mini-cluster integration: paged decode through the
block-free transfer path must match the lockstep oracle token-for-token."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_params
from repro.core.transfer import KVTransferEngine, LinkModel
from repro.models.modeling import forward_decode, forward_prefill
from repro.serving.cluster import MiniCluster, ServeRequest
from repro.serving.engine import DecodeEngine, PrefillEngine
from repro.serving.kvcache import PagedKVPool

FAMILIES = ["granite-3-8b", "qwen2-moe-a2.7b", "mamba2-2.7b",
            "jamba-1.5-large-398b"]


def _oracle(cfg, params, tokens, n_new):
    batch = {"tokens": jnp.asarray([tokens], jnp.int32)}
    first, cache = forward_prefill(cfg, params, batch)

    def pad(path, x):
        nm = path[-1].key if hasattr(path[-1], "key") else ""
        if nm in ("k", "v") and x.ndim == 4:
            return jnp.pad(x, ((0, 0), (0, 0), (0, n_new + 2), (0, 0)))
        return x

    cache = {"layers": jax.tree_util.tree_map_with_path(pad, cache["layers"]),
             "pos": cache["pos"]}
    seq = [int(first[0])]
    tok = first
    for _ in range(n_new):
        tok, cache = forward_decode(cfg, params, cache, tok)
        seq.append(int(tok[0]))
    return seq


@pytest.mark.parametrize("arch", FAMILIES)
@pytest.mark.parametrize("mode", ["block_free", "block_fixed"])
def test_engine_transfer_decode_matches_oracle(arch, mode):
    cfg, params = reduced_params(arch)
    rng = np.random.default_rng(5)
    prompts = [list(rng.integers(0, cfg.vocab_size, n)) for n in (11, 7, 11)]
    pe = PrefillEngine(cfg, params)
    outs = pe.run(prompts)
    p_pool = PagedKVPool(cfg, num_blocks=48, block_size=4)
    d_pool = PagedKVPool(cfg, num_blocks=48, block_size=4)
    eng = KVTransferEngine(LinkModel())
    de = DecodeEngine(cfg, params, d_pool, max_slots=4)
    gen = {}
    for rid, out in enumerate(outs):
        if out.k is not None:
            sb = p_pool.alloc(rid, out.prompt_len)
            p_pool.write_prefill(sb, out.k, out.v)
            db = d_pool.alloc(rid, out.prompt_len + 8)
            if mode == "block_free":
                eng.transfer_block_free(p_pool, sb, d_pool, db[:len(sb)])
            else:
                eng.transfer_block_fixed(p_pool, sb, d_pool, db[:len(sb)])
        else:
            d_pool.alloc(rid, out.prompt_len + 8)
        de.admit(rid, out, d_pool.owned(rid))
        gen[rid] = [out.first_token]
    for _ in range(4):
        for slot, tok in de.step().items():
            gen[de.rid[slot]].append(tok)
    for rid, toks in enumerate(prompts):
        assert gen[rid] == _oracle(cfg, params, toks, 4), (arch, mode, rid)


def test_minicluster_end_to_end():
    cfg, params = reduced_params("granite-3-8b")
    mc = MiniCluster(cfg, n_prefill=2, n_decode=2, params=params)
    rng = np.random.default_rng(6)
    reqs = [ServeRequest(rid=i,
                         tokens=list(rng.integers(0, cfg.vocab_size,
                                                  int(rng.integers(5, 15)))),
                         max_new_tokens=5)
            for i in range(6)]
    done = mc.run(reqs, max_ticks=100)
    assert all(r.done for r in done)
    for r in done:
        assert r.generated == _oracle(cfg, params, r.tokens, 5)


def test_minicluster_streams_tokens_in_order():
    cfg, params = reduced_params("granite-3-8b")
    mc = MiniCluster(cfg, n_prefill=1, n_decode=1, params=params)
    stream = []
    req = ServeRequest(rid=0, tokens=[1, 2, 3, 4, 5], max_new_tokens=4,
                       on_token=stream.append)
    mc.run([req], max_ticks=50)
    assert stream == req.generated          # SSE order == generation order


def test_continuous_batching_admits_mid_flight():
    """A request admitted while others are decoding must not disturb them."""
    cfg, params = reduced_params("granite-3-8b")
    rng = np.random.default_rng(8)
    pe = PrefillEngine(cfg, params)
    d_pool = PagedKVPool(cfg, num_blocks=64, block_size=4)
    de = DecodeEngine(cfg, params, d_pool, max_slots=4)
    t0 = list(rng.integers(0, cfg.vocab_size, 9))
    t1 = list(rng.integers(0, cfg.vocab_size, 12))
    o0, = pe.run([t0])
    d_pool.alloc(0, o0.prompt_len + 10)
    if o0.k is not None:
        d_pool.write_prefill(d_pool.owned(0)[: (o0.prompt_len + 3) // 4],
                             o0.k, o0.v)
    de.admit(0, o0, d_pool.owned(0))
    gen0 = [o0.first_token]
    for _ in range(2):
        for slot, tok in de.step().items():
            gen0.append(tok)
    # admit the second mid-flight
    o1, = pe.run([t1])
    d_pool.alloc(1, o1.prompt_len + 10)
    if o1.k is not None:
        d_pool.write_prefill(d_pool.owned(1)[: (o1.prompt_len + 3) // 4],
                             o1.k, o1.v)
    de.admit(1, o1, d_pool.owned(1))
    for _ in range(3):
        for slot, tok in de.step().items():
            if de.rid[slot] == 0:
                gen0.append(tok)
    assert gen0 == _oracle(cfg, params, t0, 5)


def test_whisper_engine_matches_oracle():
    """Encoder-decoder through the real engine: cross-attention KV is
    carried with the request and decode matches the lockstep oracle."""
    cfg, params = reduced_params("whisper-base")
    rng = np.random.default_rng(1)
    prompts = [list(rng.integers(0, cfg.vocab_size, 8)),
               list(rng.integers(0, cfg.vocab_size, 6))]
    frames = [np.asarray(rng.normal(size=(cfg.encoder_seq, cfg.d_model))
                         * 0.1, np.float32) for _ in prompts]
    pe = PrefillEngine(cfg, params)
    outs = pe.run(prompts, frames=frames)
    pool = PagedKVPool(cfg, num_blocks=32, block_size=4)
    de = DecodeEngine(cfg, params, pool, max_slots=4)
    gen = {}
    for rid, out in enumerate(outs):
        pool.alloc(rid, out.prompt_len + 8)
        sb = pool.owned(rid)
        pool.write_prefill(sb[: (out.prompt_len + 3) // 4], out.k, out.v)
        de.admit(rid, out, sb)
        gen[rid] = [out.first_token]
    for _ in range(4):
        for slot, tok in de.step().items():
            gen[de.rid[slot]].append(tok)
    for rid, toks in enumerate(prompts):
        batch = {"tokens": jnp.asarray([toks], jnp.int32),
                 "frames": jnp.asarray(frames[rid])[None]}
        first, cache = forward_prefill(cfg, params, batch)

        def pad(path, x):
            nm = path[-1].key if hasattr(path[-1], "key") else ""
            if nm in ("k", "v") and x.ndim == 4:
                return jnp.pad(x, ((0, 0), (0, 0), (0, 10), (0, 0)))
            return x
        cache = {"layers": jax.tree_util.tree_map_with_path(
            pad, cache["layers"]), "pos": cache["pos"]}
        seq = [int(first[0])]
        tok = first
        for _ in range(4):
            tok, cache = forward_decode(cfg, params, cache, tok)
            seq.append(int(tok[0]))
        assert seq == gen[rid], (rid, seq, gen[rid])
