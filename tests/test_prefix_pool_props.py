"""Property tests: refcount/eviction safety of the prefix-sharing
PagedKVPool under random workloads (hypothesis; skipped via the
conftest shim when hypothesis is absent).

Invariants:
  * a shared block is never freed or returned by the allocator while a
    live request references it;
  * free + uniquely-owned + cached always partitions num_blocks;
  * eviction under pressure never evicts a block a live request holds;
  * recurrent-state snapshots (PR 6) live in LOCKSTEP with their
    blocks: a snapshot never outlives its block (eviction drops it), a
    require_state hit always lands on a boundary whose snapshot is
    resident, and the snap_bytes ledger never leaks.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from conftest import reduced_params
from repro.serving.kvcache import PagedKVPool, PoolExhausted

NUM_BLOCKS = 16
BS = 4
ALIGN = 2 * BS                      # snapshot stride for the props


def _pool():
    cfg, _ = reduced_params("granite-3-8b")
    return PagedKVPool(cfg, num_blocks=NUM_BLOCKS, block_size=BS,
                       enable_prefix_cache=True)


def _snap(t):
    return {"state": np.full((3,), float(t), np.float32),
            "conv_x": np.full((2, 2), float(t), np.float32)}


def _states_for(toks):
    return {t: _snap(t) for t in range(ALIGN, len(toks) + 1, ALIGN)}


def _snaps_consistent(pool):
    """No orphan (snapshot on a non-cached block) and no ledger leak."""
    assert set(pool._snaps) <= set(pool._cached)
    assert pool.snap_bytes == sum(pool._snap_nbytes(s)
                                  for s in pool._snaps.values())


def _live_shared_blocks(pool, live):
    return {b for rid in live for b in pool.owned(rid)
            if b in pool._cached}


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_refcount_eviction_safety(data):
    pool = _pool()
    live = set()
    rid_next = 0
    # tiny token alphabet + short prompts force prefix collisions
    for _ in range(data.draw(st.integers(2, 25))):
        op = data.draw(st.sampled_from(["admit", "release", "pressure"]))
        if op == "release" and live:
            rid = data.draw(st.sampled_from(sorted(live)))
            pool.release(rid)
            live.discard(rid)
        elif op == "pressure":
            # unrelated allocation: may evict refcount-0 prefix blocks,
            # must never take a block a live request holds
            held = {b for r in live for b in pool.owned(r)}
            rid = 9000 + rid_next
            rid_next += 1
            try:
                got = pool.alloc(rid, data.draw(st.integers(1, 24)))
                assert not (set(got) & held)
                live.add(rid)
            except PoolExhausted:
                pass
        else:
            rid = rid_next
            rid_next += 1
            toks = data.draw(st.lists(st.integers(0, 3), min_size=2,
                                      max_size=20))
            before = _live_shared_blocks(pool, live)
            try:
                cached = pool.acquire_prefix(rid, toks)
                pool.alloc_to(rid, len(toks))
            except PoolExhausted:
                pool.release(rid)
                continue
            assert cached < len(toks)     # >=1 token always recomputed
            # a prefix hit may only ADD references to shared blocks,
            # never drop any other request's
            assert before <= _live_shared_blocks(pool, live | {rid})
            pool.insert_prefix(rid, toks)
            live.add(rid)
        # the partition invariant: free + private-owned + cached
        assert pool.invariant_ok(), (pool._free, pool._owned,
                                     sorted(pool._cached))
    for rid in sorted(live):
        pool.release(rid)
    assert pool.invariant_ok()
    # everything not cached is free again; cached blocks are evictable
    assert pool.free_blocks + pool.cached_blocks == NUM_BLOCKS


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_full_pool_churn_recovers_all_blocks(seed):
    """Admit/release churn at pool capacity: eviction keeps serving and
    a final drain accounts for every block."""
    rng = np.random.default_rng(seed)
    pool = _pool()
    live = []
    for i in range(12):
        toks = [int(t) for t in rng.integers(0, 4, rng.integers(2, 18))]
        try:
            pool.acquire_prefix(i, toks)
            pool.alloc_to(i, len(toks))
            pool.insert_prefix(i, toks)
            live.append(i)
        except PoolExhausted:
            pool.release(i)
            if live:
                pool.release(live.pop(0))
        assert pool.invariant_ok()
    for rid in live:
        pool.release(rid)
    assert pool.invariant_ok()
    assert pool.free_blocks + pool.cached_blocks == NUM_BLOCKS
    # force a full drain of the cache via pressure
    try:
        pool.alloc(777, NUM_BLOCKS * BS)
    except PoolExhausted:
        pass
    assert pool.invariant_ok()


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_snapshot_refcounts_track_blocks(data):
    """Random admit/acquire/release/pressure workload with snapshots
    riding every ALIGN boundary: snapshots stay in lockstep with their
    blocks through sharing, COW-degrade, and eviction."""
    pool = _pool()
    live = set()
    rid_next = 0
    for _ in range(data.draw(st.integers(2, 25))):
        op = data.draw(st.sampled_from(
            ["admit", "acquire_state", "release", "pressure"]))
        if op == "release" and live:
            rid = data.draw(st.sampled_from(sorted(live)))
            pool.release(rid)
            live.discard(rid)
        elif op == "pressure":
            rid = 9000 + rid_next
            rid_next += 1
            try:
                pool.alloc(rid, data.draw(st.integers(1, 24)))
                live.add(rid)
            except PoolExhausted:
                pass
        elif op == "acquire_state":
            # a state-requiring hit must land on a snapshot boundary
            rid = rid_next
            rid_next += 1
            toks = data.draw(st.lists(st.integers(0, 3), min_size=2,
                                      max_size=20))
            got = pool.acquire_prefix(rid, toks, align=ALIGN,
                                      require_state=True)
            assert got % ALIGN == 0
            if got:
                assert pool.snapshot_for(rid, got) is not None
                live.add(rid)
            else:
                assert pool.owned(rid) == []
        else:
            rid = rid_next
            rid_next += 1
            toks = data.draw(st.lists(st.integers(0, 3), min_size=2,
                                      max_size=20))
            try:
                pool.acquire_prefix(rid, toks, align=ALIGN,
                                    require_state=True)
                pool.alloc_to(rid, len(toks))
            except PoolExhausted:
                pool.release(rid)
                continue
            pool.insert_prefix(rid, toks, states=_states_for(toks))
            live.add(rid)
        assert pool.invariant_ok()
        _snaps_consistent(pool)
    for rid in sorted(live):
        pool.release(rid)
    _snaps_consistent(pool)
    # full drain: every cached block (and with it every snapshot) must
    # be evictable once nothing is live
    pool.alloc(7777, NUM_BLOCKS * BS)
    assert pool.cached_blocks == 0
    assert pool._snaps == {} and pool.snap_bytes == 0
    assert pool.invariant_ok()


def test_snapshot_lockstep_seeded_churn():
    """Seeded (hypothesis-free) mirror of the churn property above: the
    same acquire/release/evict/degrade workload on a fixed numpy rng,
    so the lockstep invariant executes even where hypothesis is
    unavailable (PR 3 precedent)."""
    for seed in (0, 1, 7):
        rng = np.random.default_rng(seed)
        pool = _pool()
        live = set()
        rid_next = 0
        for _ in range(30):
            op = ["admit", "acquire_state", "release",
                  "pressure"][rng.integers(0, 4)]
            if op == "release" and live:
                rid = sorted(live)[rng.integers(0, len(live))]
                pool.release(rid)
                live.discard(rid)
            elif op == "pressure":
                rid = 9000 + rid_next
                rid_next += 1
                try:
                    pool.alloc(rid, int(rng.integers(1, 25)))
                    live.add(rid)
                except PoolExhausted:
                    pass
            else:
                rid = rid_next
                rid_next += 1
                toks = [int(t) for t in rng.integers(
                    0, 4, int(rng.integers(2, 21)))]
                got = pool.acquire_prefix(rid, toks, align=ALIGN,
                                          require_state=True)
                assert got % ALIGN == 0
                if got:
                    assert pool.snapshot_for(rid, got) is not None
                if op == "acquire_state":
                    if got:
                        live.add(rid)
                    continue
                try:
                    pool.alloc_to(rid, len(toks))
                except PoolExhausted:
                    pool.release(rid)
                    continue
                pool.insert_prefix(rid, toks, states=_states_for(toks))
                live.add(rid)
            assert pool.invariant_ok()
            _snaps_consistent(pool)
        for rid in sorted(live):
            pool.release(rid)
        pool.alloc(7777, NUM_BLOCKS * BS)    # full drain
        assert pool.cached_blocks == 0
        assert pool._snaps == {} and pool.snap_bytes == 0
        assert pool.invariant_ok()


def test_eviction_drops_boundary_snapshot_seeded():
    """Seeded (hypothesis-free) lockstep check: evicting the block that
    holds a boundary snapshot drops the snapshot and its bytes — and a
    later require_state acquire floors past the dead boundary."""
    pool = _pool()
    toks = list(range(ALIGN * 2))            # boundaries at 8 and 16
    pool.alloc(0, len(toks))
    pool.insert_prefix(0, toks, states=_states_for(toks))
    assert pool.snap_stores == 2 and pool.snap_bytes > 0
    pool.release(0)
    bytes_full = pool.snap_bytes
    # leaf-first eviction: one block of pressure kills the TAIL block,
    # which carries the 16-boundary snapshot
    pool.alloc(1, BS * (NUM_BLOCKS - pool.cached_blocks) + BS)
    assert pool.evictions >= 1
    _snaps_consistent(pool)
    assert pool.snap_bytes < bytes_full
    got = pool.acquire_prefix(2, toks + [99], align=ALIGN,
                              require_state=True)
    assert got == ALIGN                      # floored past dead 16
    assert pool.snapshot_for(2, got)["state"][0] == float(ALIGN)
    assert pool.invariant_ok()
