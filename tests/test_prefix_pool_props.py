"""Property tests: refcount/eviction safety of the prefix-sharing
PagedKVPool under random workloads (hypothesis; skipped via the
conftest shim when hypothesis is absent).

Invariants:
  * a shared block is never freed or returned by the allocator while a
    live request references it;
  * free + uniquely-owned + cached always partitions num_blocks;
  * eviction under pressure never evicts a block a live request holds.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from conftest import reduced_params
from repro.serving.kvcache import PagedKVPool, PoolExhausted

NUM_BLOCKS = 16
BS = 4


def _pool():
    cfg, _ = reduced_params("granite-3-8b")
    return PagedKVPool(cfg, num_blocks=NUM_BLOCKS, block_size=BS,
                       enable_prefix_cache=True)


def _live_shared_blocks(pool, live):
    return {b for rid in live for b in pool.owned(rid)
            if b in pool._cached}


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_refcount_eviction_safety(data):
    pool = _pool()
    live = set()
    rid_next = 0
    # tiny token alphabet + short prompts force prefix collisions
    for _ in range(data.draw(st.integers(2, 25))):
        op = data.draw(st.sampled_from(["admit", "release", "pressure"]))
        if op == "release" and live:
            rid = data.draw(st.sampled_from(sorted(live)))
            pool.release(rid)
            live.discard(rid)
        elif op == "pressure":
            # unrelated allocation: may evict refcount-0 prefix blocks,
            # must never take a block a live request holds
            held = {b for r in live for b in pool.owned(r)}
            rid = 9000 + rid_next
            rid_next += 1
            try:
                got = pool.alloc(rid, data.draw(st.integers(1, 24)))
                assert not (set(got) & held)
                live.add(rid)
            except PoolExhausted:
                pass
        else:
            rid = rid_next
            rid_next += 1
            toks = data.draw(st.lists(st.integers(0, 3), min_size=2,
                                      max_size=20))
            before = _live_shared_blocks(pool, live)
            try:
                cached = pool.acquire_prefix(rid, toks)
                pool.alloc_to(rid, len(toks))
            except PoolExhausted:
                pool.release(rid)
                continue
            assert cached < len(toks)     # >=1 token always recomputed
            # a prefix hit may only ADD references to shared blocks,
            # never drop any other request's
            assert before <= _live_shared_blocks(pool, live | {rid})
            pool.insert_prefix(rid, toks)
            live.add(rid)
        # the partition invariant: free + private-owned + cached
        assert pool.invariant_ok(), (pool._free, pool._owned,
                                     sorted(pool._cached))
    for rid in sorted(live):
        pool.release(rid)
    assert pool.invariant_ok()
    # everything not cached is free again; cached blocks are evictable
    assert pool.free_blocks + pool.cached_blocks == NUM_BLOCKS


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_full_pool_churn_recovers_all_blocks(seed):
    """Admit/release churn at pool capacity: eviction keeps serving and
    a final drain accounts for every block."""
    rng = np.random.default_rng(seed)
    pool = _pool()
    live = []
    for i in range(12):
        toks = [int(t) for t in rng.integers(0, 4, rng.integers(2, 18))]
        try:
            pool.acquire_prefix(i, toks)
            pool.alloc_to(i, len(toks))
            pool.insert_prefix(i, toks)
            live.append(i)
        except PoolExhausted:
            pool.release(i)
            if live:
                pool.release(live.pop(0))
        assert pool.invariant_ok()
    for rid in live:
        pool.release(rid)
    assert pool.invariant_ok()
    assert pool.free_blocks + pool.cached_blocks == NUM_BLOCKS
    # force a full drain of the cache via pressure
    try:
        pool.alloc(777, NUM_BLOCKS * BS)
    except PoolExhausted:
        pass
    assert pool.invariant_ok()
