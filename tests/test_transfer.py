"""Block-free vs block-fixed transfer: bit-exact delivery, timing model
properties (Fig. 4), and pool invariants under hypothesis."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from conftest import reduced_params
from repro.core.transfer import KVTransferEngine, LinkModel
from repro.serving.kvcache import PagedKVPool, PoolExhausted


def _pools(arch="granite-3-8b", nb=32, bs=4):
    cfg, _ = reduced_params(arch)
    return (PagedKVPool(cfg, num_blocks=nb, block_size=bs),
            PagedKVPool(cfg, num_blocks=nb, block_size=bs), cfg)


def _fill(pool, rid, tokens, seed=0):
    cfg = pool.cfg
    rng = np.random.default_rng(seed)
    blocks = pool.alloc(rid, tokens)
    k = jnp.asarray(rng.normal(size=(pool.attn_layers, tokens, cfg.kv_dim)),
                    jnp.float32)
    v = jnp.asarray(rng.normal(size=(pool.attn_layers, tokens, cfg.kv_dim)),
                    jnp.float32)
    pool.write_prefill(blocks, k, v)
    return blocks, k, v


def test_both_modes_deliver_identical_bytes():
    src, dst_a, cfg = _pools()
    dst_b = PagedKVPool(cfg, num_blocks=32, block_size=4)
    blocks, k, v = _fill(src, rid=1, tokens=13)
    eng = KVTransferEngine(LinkModel())
    da = dst_a.alloc(1, 13)
    db = dst_b.alloc(1, 13)
    eng.transfer_block_free(src, blocks, dst_a, da)
    eng.transfer_block_fixed(src, blocks, dst_b, db)
    got_a = np.asarray(dst_a.read_tokens(da, 13))
    got_b = np.asarray(dst_b.read_tokens(db, 13))
    np.testing.assert_array_equal(got_a, got_b)
    want = np.concatenate([np.asarray(k), np.asarray(v)], -1)
    np.testing.assert_allclose(got_a, want, rtol=1e-6)


def test_block_free_is_faster_and_fewer_messages():
    src, dst, cfg = _pools()
    blocks, _, _ = _fill(src, rid=2, tokens=25)
    eng = KVTransferEngine(LinkModel())
    d1 = dst.alloc(2, 25)
    r_free = eng.transfer_block_free(src, blocks, dst, d1)
    dst.release(2)
    d2 = dst.alloc(2, 25)
    r_fix = eng.transfer_block_fixed(src, blocks, dst, d2)
    assert r_free.nbytes == r_fix.nbytes
    assert r_free.n_msgs < r_fix.n_msgs
    assert r_free.time_s < r_fix.time_s


@given(nbytes=st.integers(1 << 10, 1 << 28),
       block=st.sampled_from([4096, 65536, 1 << 20]),
       layers=st.integers(1, 80))
@settings(max_examples=50, deadline=None)
def test_link_model_block_free_never_slower(nbytes, block, layers):
    eng = KVTransferEngine(LinkModel())
    t_free = eng.time_only(nbytes, block_bytes=block, layers=layers,
                           mode="block_free")
    t_fix = eng.time_only(nbytes, block_bytes=block, layers=layers,
                          mode="block_fixed")
    t_pl = eng.time_only(nbytes, block_bytes=block, layers=layers,
                         mode="block_free", per_layer=True)
    assert t_free <= t_fix
    assert t_free <= t_pl <= t_fix


def test_utilization_drops_with_smaller_blocks():
    """Fig. 4b: smaller blocks -> more control messages -> lower D2D
    bandwidth utilization."""
    link = LinkModel()
    nbytes = 64 << 20
    utils = [link.utilization(nbytes, max(1, nbytes // bb))
             for bb in (1 << 12, 1 << 16, 1 << 20, nbytes)]
    assert all(a < b + 1e-12 for a, b in zip(utils, utils[1:]))
    assert utils[-1] > 0.95


def test_multihop_conflicts_increase_variance():
    """Fig. 14d: multi-hop transfers show heavy-tail variance."""
    import random
    one = LinkModel(hops=1)
    multi = LinkModel(hops=3, conflict_prob=0.25)
    rng = random.Random(0)
    t1 = [one.time(8 << 20, 1, rng) for _ in range(300)]
    t2 = [multi.time(8 << 20, 1, rng) for _ in range(300)]
    assert np.std(t2) > 10 * np.std(t1)


# ------------------------------------------- overlap-model reconciliation
def test_scheduler_reports_link_model_per_layer_timing():
    """The simulator (LinkModel.per_layer_tail / time_only) and the real
    path (TransferScheduler) must report the SAME per-layer overlap
    model — the PR-2 HLO-cost drift failure mode was exactly this kind
    of silent divergence between the model and the measured path."""
    from types import SimpleNamespace

    from repro.serving.transfer_sched import TransferScheduler

    src, dst_pool, cfg = _pools()
    link = LinkModel()
    eng = KVTransferEngine(link)
    tokens = 13
    for compute_s in (0.0, 0.004, 10.0):
        pool = PagedKVPool(cfg, num_blocks=32, block_size=4)
        dst = SimpleNamespace(iid="D0", pool=pool, draining=False)
        sched = TransferScheduler(link)
        rng = np.random.default_rng(0)
        L = pool.attn_layers
        k = jnp.asarray(rng.normal(size=(L, tokens, cfg.kv_dim)),
                        jnp.float32)
        v = jnp.asarray(rng.normal(size=(L, tokens, cfg.kv_dim)),
                        jnp.float32)
        out = SimpleNamespace(k=k, v=v, prompt_len=tokens, mamba_state={},
                              cross=None)
        req = SimpleNamespace(rid=1, max_new_tokens=0)
        job = sched.begin(req, out, src_iid="P0", dst=dst, t_start=0.0,
                          compute_s=compute_s)
        while not sched.idle():
            sched.pump(sched.next_event())
        nbytes = L * pool.layer_nbytes(pool.blocks_for_tokens(tokens))
        # completion == the shared closed form (simulator model)
        want = link.per_layer_completion(nbytes, L, compute_s)
        assert abs(job.admitted_t - want) < 1e-12
        # admission wait == the residual the simulator charges decode
        assert abs(job.admission_wait
                   - link.per_layer_tail(nbytes, L, compute_s)) < 1e-12
        # with no compute to hide behind, the scheduler's busy time is
        # exactly time_only(per_layer=True): n_msgs == layers
        if compute_s == 0.0:
            t_pl = eng.time_only(nbytes, block_bytes=4 * pool.width * 4,
                                 layers=L, mode="block_free",
                                 per_layer=True)
            assert abs(job.admitted_t - t_pl) < 1e-12
            assert abs(job.transfer_busy_s - t_pl) < 1e-12


# ----------------------------------------------------------- pool safety
@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_pool_alloc_release_invariants(data):
    cfg, _ = reduced_params("granite-3-8b")
    pool = PagedKVPool(cfg, num_blocks=24, block_size=4)
    live = set()
    for step in range(data.draw(st.integers(1, 30))):
        if live and data.draw(st.booleans()):
            rid = data.draw(st.sampled_from(sorted(live)))
            pool.release(rid)
            live.discard(rid)
        else:
            rid = step + 1000
            tokens = data.draw(st.integers(1, 30))
            try:
                pool.alloc(rid, tokens)
                live.add(rid)
            except PoolExhausted:
                pass
        assert pool.invariant_ok()
    for rid in list(live):
        pool.release(rid)
    assert pool.free_blocks == 24
