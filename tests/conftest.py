import os
import sys

# deterministic, single real device (the dry-run sets its own flags in a
# separate process; tests must see 1 CPU device)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.configs import ALIASES, get_config  # noqa: E402
from repro.models.params import init_params  # noqa: E402

# ---- optional hypothesis ----------------------------------------------
# Five test modules import `from hypothesis import given, settings,
# strategies as st` at module level; without this shim the whole suite
# errors at collection when hypothesis is not installed. Install a stub
# module whose @given marks each property test as skipped, so the rest
# of the suite still runs.
try:
    import hypothesis  # noqa: E402,F401
except ImportError:
    import types  # noqa: E402

    def _given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def _settings(*args, **kwargs):
        if args and callable(args[0]):
            return args[0]
        return lambda fn: fn

    class _Strategies(types.ModuleType):
        def __getattr__(self, name):
            return lambda *a, **k: None

    _st = _Strategies("hypothesis.strategies")
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

ALL_ARCHS = sorted(ALIASES)
DECODER_ARCHS = [a for a in ALL_ARCHS if a != "whisper-base"]


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


_param_cache = {}


def reduced_params(arch: str):
    """Session-cached (cfg, params) for a reduced arch."""
    if arch not in _param_cache:
        cfg = get_config(arch).reduced()
        _param_cache[arch] = (cfg, init_params(cfg, jax.random.PRNGKey(7)))
    return _param_cache[arch]
