import os
import sys

# deterministic, single real device (the dry-run sets its own flags in a
# separate process; tests must see 1 CPU device)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.configs import ALIASES, get_config  # noqa: E402
from repro.models.params import init_params  # noqa: E402

ALL_ARCHS = sorted(ALIASES)
DECODER_ARCHS = [a for a in ALL_ARCHS if a != "whisper-base"]


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


_param_cache = {}


def reduced_params(arch: str):
    """Session-cached (cfg, params) for a reduced arch."""
    if arch not in _param_cache:
        cfg = get_config(arch).reduced()
        _param_cache[arch] = (cfg, init_params(cfg, jax.random.PRNGKey(7)))
    return _param_cache[arch]
