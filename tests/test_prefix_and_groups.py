"""Prefix-cache invariants (hypothesis), fine-grained grouping benefit,
group workflows, MLOps recovery, zookeeper consistency."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.cluster_sim import ClusterSim, SimConfig, run_workload
from repro.core.group import PDGroup
from repro.core.mlops import MLOps, NodeMonitor
from repro.core.prefix_cache import PrefixCache
from repro.core.profiles import profile_for
from repro.core.requests import DEFAULT_SCENARIOS, WorkloadGenerator
from repro.core.zookeeper import MetaStore


# ----------------------------------------------------------- prefix cache
@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_prefix_cache_budget_and_lru(data):
    budget = data.draw(st.integers(1 << 10, 1 << 16))
    bpt = data.draw(st.sampled_from([16, 64, 256]))
    pc = PrefixCache(budget, bpt)
    for _ in range(data.draw(st.integers(1, 60))):
        pid = f"p{data.draw(st.integers(0, 12))}"
        plen = data.draw(st.integers(1, 64))
        if data.draw(st.booleans()):
            pc.lookup(pid, plen)
        else:
            pc.insert(pid, plen)
        assert pc.invariant_ok()
        assert pc.used <= budget


def test_prefix_cache_eviction_is_lru():
    pc = PrefixCache(budget_bytes=300, kv_bytes_per_token=10)
    pc.insert("a", 10)   # 100 bytes
    pc.insert("b", 10)
    pc.insert("c", 10)   # full
    pc.lookup("a", 10)   # refresh a
    pc.insert("d", 10)   # evicts b (LRU), not a
    assert "a" in pc and "d" in pc and "b" not in pc


def test_fine_grained_groups_beat_mixed_pool():
    """C1: per-scenario groups keep prefixes hot; a mixed pool under the
    same total HBM thrashes and loses TTFT/throughput."""
    arch = get_config("pangu-38b")
    prof = profile_for(arch)
    budget = 64 * prof.kv_bytes_per_token * 1024  # tight-ish HBM for prefixes

    def run(scenarios, n_p, n_d, seed):
        gen = WorkloadGenerator(scenarios, base_rps=30, seed=seed)
        reqs = gen.arrivals(40.0)
        sim = ClusterSim(SimConfig(profile=prof, hbm_prefix_budget=budget),
                         n_prefill=n_p, n_decode=n_d, policy="ondemand",
                         seed=seed)
        return run_workload(sim, reqs, 60.0)

    # mixed: all six scenarios into one pool of 6P/12D
    mixed = run(DEFAULT_SCENARIOS, 6, 12, seed=1)
    # fine-grained: one group of 1P/2D per scenario (same totals)
    fine = [run([sc], 1, 2, seed=1) for sc in DEFAULT_SCENARIOS]
    fine_hit = sum(f["prefix_hit_rate"] for f in fine) / len(fine)
    fine_thr = sum(f["throughput_rps"] for f in fine)
    assert fine_hit > mixed["prefix_hit_rate"] + 0.05
    assert fine_thr > mixed["throughput_rps"] * 0.95


# ---------------------------------------------------------------- groups
def test_group_setup_workflow():
    meta = MetaStore()
    g = PDGroup("svcA/chat#g0", "svcA/chat", meta)
    t_done = g.setup(0.0, n_prefill=2, n_decode=3)
    assert t_done > 0
    assert len(g.members("P")) == 2 and len(g.members("D")) == 3
    steps = [e.step for e in g.timeline]
    assert steps == ["gathered", "connected", "model_loaded", "serving"]
    # every instance has device-ordered RoCE IPs
    for iid in g.members("P") + g.members("D"):
        assert len(meta.instances[iid].roce_ips) == 8


def test_ratio_adjustment_dynamic_roce():
    meta = MetaStore()
    g = PDGroup("g1", "s", meta)
    g.setup(0.0, 3, 3)
    t = g.adjust_ratio(100.0, 2, 4)
    assert g.ratio == (2, 4)
    assert t > 100.0
    # shrink only: no model load needed
    t2 = g.adjust_ratio(t, 2, 3)
    assert g.ratio == (2, 3)
    assert t2 - t < 10.0


def test_recovery_minimum_cost():
    meta = MetaStore()
    g = PDGroup("g2", "s", meta)
    g.setup(0.0, 2, 2)
    ml = MLOps(meta, NodeMonitor(seed=1, fault_rate_per_hour=0.0))
    victim = g.members("D")[0]
    before = set(meta.instances)
    rec = ml.recover(10.0, g, victim, "device_reset")
    after = set(meta.instances)
    # exactly one removed, exactly one substitute added
    assert before - after == {victim}
    assert len(after - before) == 1
    assert rec.recovery_time > 0
    assert g.ratio == (2, 2)           # service shape restored
    assert victim not in meta.group_members("g2", "D")


def test_auto_detection_recovers_injected_faults():
    meta = MetaStore()
    g = PDGroup("g3", "s", meta)
    g.setup(0.0, 4, 4)
    ml = MLOps(meta, NodeMonitor(seed=3, fault_rate_per_hour=25.0))
    recs = []
    t = 0.0
    for _ in range(20):
        t += 360.0
        recs += ml.check_and_recover(t, g, dt_hours=0.1)
    assert recs, "fault injection should have triggered"
    assert g.ratio == (4, 4)


def test_zookeeper_remove_blocks_forwarding():
    meta = MetaStore()
    meta.register_group("g", None)
    m = meta.gather_instance(0.0, "i0", "P", "g")
    assert "i0" in meta.group_members("g", "P")
    meta.remove_instance(1.0, "i0")
    assert "i0" not in meta.group_members("g", "P")
    assert "i0" not in meta.instances


# --------------------------------------------------- tiered pool (§6.2)
def test_tiered_cache_spills_and_promotes():
    from repro.core.prefix_cache import TieredPrefixCache
    tc = TieredPrefixCache(hbm_budget=200, host_budget=1000,
                           kv_bytes_per_token=10)
    tc.insert("a", 10)            # 100B
    tc.insert("b", 10)            # 100B -> HBM full
    tc.insert("c", 10)            # evicts "a" -> host tier
    got, load = tc.lookup("a", 10)
    assert got == 10 and load > 0          # host hit pays a load penalty
    got, load = tc.lookup("a", 10)
    assert got == 10 and load == 0.0       # promoted back to HBM
    assert tc.invariant_ok()


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_tiered_cache_invariants(data):
    from repro.core.prefix_cache import TieredPrefixCache
    tc = TieredPrefixCache(hbm_budget=data.draw(st.integers(100, 2000)),
                           host_budget=data.draw(st.integers(100, 5000)),
                           kv_bytes_per_token=10)
    for _ in range(data.draw(st.integers(1, 40))):
        pid = f"p{data.draw(st.integers(0, 8))}"
        ln = data.draw(st.integers(1, 50))
        if data.draw(st.booleans()):
            got, load = tc.lookup(pid, ln)
            assert got >= 0 and load >= 0.0
        else:
            tc.insert(pid, ln)
        assert tc.invariant_ok()
