"""Overload control at the gateway + long-run telemetry bounds (ISSUE 10).

The degradation ladder when demand exceeds a cluster that cannot grow:
absorb -> (scale) -> backpressure -> shed. These tests pin the last two
rungs — a saturated cluster terminates by SHEDDING (ledgered, only
requests already past their SLO deadline) instead of spinning the event
heap, the bounded admission queue signals backpressure, and every
telemetry buffer on the hot path stays windowed so a long-running
frontend does not grow without bound.
"""
import numpy as np

from conftest import reduced_params
from repro.serving.cluster import ServeRequest
from repro.serving.faults import DeterministicService
from repro.serving.frontend import ClusterFrontend

# prefill slow enough that a 1x1 cluster caps out near ~40 req/s
SVC = DeterministicService(prefill_base_s=0.02, prefill_per_token_s=5e-4)


def _reqs(cfg, n, *, seed=3, max_new=4, rid0=0, deadline=0.25):
    rng = np.random.default_rng(seed)
    return [ServeRequest(
        rid=rid0 + i,
        tokens=list(map(int, rng.integers(0, cfg.vocab_size,
                                          int(rng.integers(5, 12))))),
        max_new_tokens=max_new, slo_deadline_s=deadline)
        for i in range(n)]


def _saturate(fe, cfg, *, n=80, deadline=0.25):
    rs = _reqs(cfg, n, deadline=deadline)
    for i, r in enumerate(rs):
        fe.submit(r, at=0.001 * i)             # 1000 req/s into ~40/s
    fe.serve(watch=rs, max_events=400_000)
    return rs


def test_saturated_cluster_sheds_instead_of_spinning():
    """The regression the capped backoff exists for: a cluster that can
    never catch up TERMINATES, shedding exactly the requests whose SLO
    deadline passed — never a request that still had time."""
    cfg, params = reduced_params("granite-3-8b")
    fe = ClusterFrontend(cfg, topology={"default": (1, 1)}, params=params,
                         prefill_kwargs={"batch_size": 1},
                         service_model=SVC)
    rs = _saturate(fe, cfg)
    assert all(r.done for r in rs)             # serve() returned
    shed = [r for r in rs if r.shed]
    served = [r for r in rs if not r.shed]
    assert shed, "an unservable burst must shed"
    assert served, "shedding everything means admission is broken"
    gw = fe.gateway_stats()
    assert gw["gw_sheds"] == len(shed)
    for r in shed:
        # SLO-aware: shed only at/after the deadline, and ledgered
        assert r.finish_t >= r.submit_t + r.slo_deadline_s - 1e-9
        assert not r.generated                 # never half-served
    for r in served:
        assert len(r.generated) >= 1
    for node in (fe.groups["default"].prefills
                 + fe.groups["default"].decodes):
        assert node.pool.invariant_ok()


def test_backoff_is_capped_and_seeded():
    """Retry timestamps never step more than the cap apart (plus jitter)
    and two same-seed frontends requeue identically."""
    cfg, params = reduced_params("granite-3-8b")
    sigs = []
    for _ in range(2):
        fe = ClusterFrontend(cfg, topology={"default": (1, 1)},
                             params=params,
                             prefill_kwargs={"batch_size": 1},
                             service_model=SVC, seed=5,
                             gw_backoff_cap_s=0.04)
        rs = _saturate(fe, cfg, n=40)
        sigs.append((fe.gw_requeues, fe.gw_sheds,
                     tuple(sorted((r.rid, r.shed, tuple(r.generated))
                                  for r in rs))))
    assert sigs[0] == sigs[1]
    assert sigs[0][0] >= 1


def test_bounded_queue_signals_backpressure():
    cfg, params = reduced_params("granite-3-8b")
    fe = ClusterFrontend(cfg, topology={"default": (1, 1)}, params=params,
                         prefill_kwargs={"batch_size": 1},
                         service_model=SVC, queue_bound=4)
    _saturate(fe, cfg, n=60)
    assert fe.gateway_stats()["gw_backpressure"] >= 1


def test_deadline_less_requests_park_not_spin():
    """Without an SLO deadline nothing may shed — past the attempt cap
    the request parks in ``pending`` and completes when capacity frees
    up, bounding the event heap."""
    cfg, params = reduced_params("granite-3-8b")
    fe = ClusterFrontend(cfg, topology={"default": (1, 1)}, params=params,
                         prefill_kwargs={"batch_size": 1},
                         service_model=SVC)
    rs = _reqs(cfg, 30, deadline=-1.0)
    for i, r in enumerate(rs):
        fe.submit(r, at=0.001 * i)
    fe.serve(watch=rs, max_events=400_000)
    assert all(r.done for r in rs)
    assert not any(r.shed for r in rs)
    assert fe.gateway_stats()["gw_sheds"] == 0


# ------------------------------------------------- telemetry retention

def test_long_run_telemetry_stays_bounded():
    """Memory regression: after far more traffic than any retention
    window, every hot-path buffer has been trimmed — while the windowed
    medians that feed the goodput model still read the recent tail."""
    cfg, params = reduced_params("granite-3-8b")
    fe = ClusterFrontend(cfg, topology={"default": (1, 1)}, params=params,
                         prefill_kwargs={"batch_size": 1},
                         service_model=SVC, adjust_ratio=True)
    g = fe.groups["default"]
    # synthetic long run: push every ledger way past its window
    for i in range(6000):
        fe.meta._audit(float(i), f"evt {i}")
        g.flips.append((float(i), "P->D", f"n{i}"))
        if len(g.flips) > 512:
            del g.flips[:-256]
    adj = fe.adjusters["default"]
    for i in range(2000):
        adj.decisions.append((i, "P->D"))
        adj.wait_votes.append(i)
    adj.maybe_adjust(adj.interval)             # triggers the trim
    assert len(fe.meta.events) <= 4096
    # monotonic count survives the trim (2 gathers at construction)
    assert fe.meta.n_events == 6000 + 2
    assert len(g.flips) <= 512
    assert len(adj.decisions) <= 512
    assert len(adj.wait_votes) <= 512
    # the stats the goodput model reads are computed from [-32:] tails,
    # which the retention windows are far wider than
    rs = _reqs(cfg, 6, deadline=4.0)
    for i, r in enumerate(rs):
        fe.submit(r, at=0.002 * i)
    fe.serve(watch=rs, max_events=100_000)
    st = g.transfer_stats()
    assert st["prefill_batch_median_s"] > 0.0
    assert st["decode_step_median_s"] > 0.0


def test_fault_ledger_trims_on_dispatch():
    from repro.serving.faults import FaultPlan
    cfg, params = reduced_params("granite-3-8b")
    fe = ClusterFrontend(cfg, topology={"default": (1, 1)}, params=params,
                         prefill_kwargs={"batch_size": 1},
                         service_model=SVC, faults=FaultPlan([]),
                         health_timeout_s=0.05,
                         fault_kwargs={"heartbeat_s": 0.02})
    ft = fe.groups["default"].ft
    ft.log.extend((0.0, "x", "y") for _ in range(6000))
    ft.recovery_walls.extend(0.01 for _ in range(2000))
    ft.dispatch("hb", 0.0, None)
    assert len(ft.log) <= 4096
    assert len(ft.recovery_walls) <= 512
