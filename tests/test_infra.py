"""Infrastructure pieces: sharding rules (hypothesis), HLO cost model,
checkpoint roundtrip, data determinism, caches, optimizer."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from conftest import reduced_params
from repro.checkpoint import load_params, save_params
from repro.core.requests import WorkloadGenerator, tidal_rate
from repro.data import SyntheticLM
from repro.distribution.sharding import PARAM_RULES_2D, spec_from_axes
from repro.launch.hlo_cost import analyze_text
from repro.launch.mesh import make_test_mesh
from repro.models.caches import cache_num_bytes, zeros_cache
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


# ------------------------------------------------------------- sharding
class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


@settings(max_examples=80, deadline=None)
@given(data=st.data())
def test_spec_from_axes_divisibility(data):
    """Property: every mesh axis used in the spec divides its dim, no mesh
    axis is used twice, unshardable dims fall back to None."""
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    ndim = data.draw(st.integers(1, 4))
    dims = [data.draw(st.integers(1, 4096)) for _ in range(ndim)]
    names = [data.draw(st.sampled_from(
        ["embed", "ff", "vocab", "q_heads", "layers", None]))
        for _ in range(ndim)]
    spec = spec_from_axes(names, dims, mesh, PARAM_RULES_2D)
    used = []
    for dim, part in zip(dims, tuple(spec) + (None,) * (ndim - len(spec))):
        if part is None:
            continue
        axes = part if isinstance(part, tuple) else (part,)
        prod = 1
        for a in axes:
            prod *= mesh.shape[a]
            used.append(a)
        assert dim % prod == 0, (dims, names, spec)
    assert len(used) == len(set(used)), spec


def test_spec_prefers_full_2d_when_divisible():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = spec_from_axes(("embed", "ff"), (8192, 49152), mesh,
                          PARAM_RULES_2D)
    assert spec == P(("pod", "data"), "model")


# ------------------------------------------------------------- hlo cost
def test_hlo_cost_multiplies_loops():
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((256,), jnp.float32)

    def unrolled(w, x):
        for _ in range(8):
            x = jnp.tanh(w @ x)
        return x

    def scanned(w, x):
        def body(c, _):
            return jnp.tanh(w @ c), None
        return jax.lax.scan(body, x, None, length=8)[0]

    f_u = analyze_text(jax.jit(unrolled).lower(w, x).compile().as_text())
    f_s = analyze_text(jax.jit(scanned).lower(w, x).compile().as_text())
    assert abs(f_u.flops - f_s.flops) / f_u.flops < 0.05
    assert f_s.flops > 8 * 2 * 256 * 256 * 0.9


# ----------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    cfg, params = reduced_params("minicpm-2b")
    path = str(tmp_path / "ckpt.npz")
    save_params(path, params, step=7)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), params)
    back = load_params(path, like)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------------ data
def test_data_is_deterministic_and_shardable():
    d1 = SyntheticLM(512, 32, 8, seed=3)
    d2 = SyntheticLM(512, 32, 8, seed=3)
    b1, b2 = d1.batch(5), d2.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # different steps differ
    assert not np.array_equal(d1.batch(6)["tokens"], b1["tokens"])


def test_workload_scenarios_share_prefixes():
    gen = WorkloadGenerator(base_rps=50, seed=0)
    reqs = gen.arrivals(20.0)
    by_prefix = {}
    for r in reqs:
        by_prefix.setdefault(r.prefix_id, []).append(r)
    shared = [v for v in by_prefix.values() if len(v) > 1]
    assert shared, "prefixes must repeat across requests"
    for grp in shared:
        assert len({r.prefix_len for r in grp}) == 1


def test_tidal_rate_shape():
    base = 10.0
    peak = tidal_rate(base, 43200.0)      # mid-day
    trough = tidal_rate(base, 0.0)
    assert peak > 0.9 * base and trough < 0.3 * base


# ------------------------------------------------------------ optimizer
def test_adamw_descends_quadratic():
    p = {"w": jnp.asarray([3.0, -2.0])}
    st_ = adamw_init(p)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(60):
        g = {"w": 2 * p["w"]}
        p, st_, _ = adamw_update(p, g, st_, cfg)
    assert float(jnp.abs(p["w"]).max()) < 0.3


def test_grad_clipping_bounds_update():
    p = {"w": jnp.zeros(4)}
    st_ = adamw_init(p)
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    g = {"w": jnp.full(4, 1e6)}
    p2, _, gnorm = adamw_update(p, g, st_, cfg)
    assert float(gnorm) > 1e5
    assert float(jnp.abs(p2["w"]).max()) <= 1.5


# --------------------------------------------------------------- caches
def test_cache_bytes_accounting():
    cfg, _ = reduced_params("granite-3-8b")
    full = cache_num_bytes(cfg, 4, 128)
    windowed = cache_num_bytes(cfg, 4, 128, window=32)
    assert windowed < full
    c = zeros_cache(cfg, 4, 128)
    leaves = jax.tree.leaves(c)
    assert all(bool(jnp.all(x == 0)) for x in leaves if x.ndim)


def test_hlo_cost_dot_flops_exact():
    """The analyzer's dot accounting matches hand math exactly."""
    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 48), jnp.float32)
    c = jax.jit(lambda x, y: x @ y).lower(a, b).compile()
    got = analyze_text(c.as_text())
    assert got.flops == 2 * 64 * 32 * 48


def test_hlo_cost_counts_collectives_in_loops():
    from jax.sharding import NamedSharding, PartitionSpec as P
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    # single-device mesh: no collectives expected — asserts no false
    # positives from the parser
    mesh = jax.sharding.Mesh(__import__("numpy").asarray(
        jax.devices()[:1]).reshape(1), ("model",))

    def f(w, x):
        def body(c, _):
            return jnp.tanh(w @ c), None
        return jax.lax.scan(body, x, None, length=4)[0]
    c = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, "model")),
                                 NamedSharding(mesh, P()))).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((64,), jnp.float32)).compile()
    got = analyze_text(c.as_text())
    assert got.coll_bytes == 0
    assert got.flops >= 4 * 2 * 64 * 64


# -------------------------------------------------------------- sampling
def test_sampling_policies():
    from repro.serving.sampling import greedy, sample
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]])
    assert int(greedy(logits)[0]) == 1
    assert int(sample(logits, key, temperature=0.0)[0]) == 1
    # top-1 sampling is greedy regardless of temperature
    assert int(sample(logits, key, temperature=2.0, top_k=1)[0]) == 1
    # high-temperature samples stay within the top-k support
    toks = [int(sample(logits, jax.random.PRNGKey(i), temperature=5.0,
                       top_k=2)[0]) for i in range(20)]
    assert set(toks) <= {1, 2}
