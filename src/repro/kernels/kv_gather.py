"""Pallas kernel: gather discrete KV blocks into ONE contiguous buffer.

The C3 sender hot path (paper §3.6, Fig. 10): the RDMA engine wants a
single contiguous byte range; this kernel linearizes a request's paged
blocks into that buffer. TPU mapping: the block table rides in scalar-
prefetch SMEM (it drives the BlockSpec index_map), each grid step DMAs one
(block_size, width) page HBM->VMEM->HBM; width = 2*kv_dim is a multiple of
128 lanes for every assigned arch, and block_size=16 fills the sublanes of
a bf16/f32 tile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, src_ref, out_ref):
    out_ref[...] = src_ref[0]


def kv_gather_pallas(storage: jax.Array, idx: jax.Array, *,
                     interpret: bool = True) -> jax.Array:
    """storage: (L, NB, BS, W); idx: (n,) int32 -> (L, n*BS, W)."""
    L, NB, BS, W = storage.shape
    n = idx.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(L, n),
        in_specs=[
            pl.BlockSpec((1, 1, BS, W),
                         lambda l, i, idx_ref: (l, idx_ref[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BS, W), lambda l, i, idx_ref: (l, i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((L, n * BS, W), storage.dtype),
        interpret=interpret,
    )(idx, storage)
