"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def kv_gather(storage: jax.Array, idx: jax.Array) -> jax.Array:
    """storage: (L, NB, BS, W); idx: (n,) int32 -> (L, n*BS, W)."""
    g = jnp.take(storage, idx, axis=1)
    L, n, bs, w = g.shape
    return g.reshape(L, n * bs, w)


def kv_scatter(storage: jax.Array, buf: jax.Array,
               idx: jax.Array) -> jax.Array:
    """storage: (L, NB, BS, W); buf: (L, n*BS, W); idx: (n,) -> storage'."""
    L, t, w = buf.shape
    n = idx.shape[0]
    bs = storage.shape[2]
    return storage.at[:, idx].set(buf.reshape(L, n, bs, w))


def paged_attention(q: jax.Array, kv_pages: jax.Array,
                    block_table: jax.Array, lens: jax.Array) -> jax.Array:
    """Decode attention over a paged KV pool (one layer).

    q: (B, nq, hd); kv_pages: (NB, BS, 2*kv_dim); block_table: (B, MAXB)
    int32 (-1 padded); lens: (B,) valid token counts. Returns (B, nq, hd).
    """
    B, nq, hd = q.shape
    NB, BS, W = kv_pages.shape
    kvd = W // 2
    nkv = kvd // hd
    g = nq // nkv
    MAXB = block_table.shape[1]
    scale = 1.0 / math.sqrt(hd)

    bt = jnp.clip(block_table, 0, NB - 1)
    gathered = kv_pages[bt]                     # (B, MAXB, BS, W)
    kv = gathered.reshape(B, MAXB * BS, W)
    k = kv[..., :kvd].reshape(B, MAXB * BS, nkv, hd)
    v = kv[..., kvd:].reshape(B, MAXB * BS, nkv, hd)
    qg = q.reshape(B, nkv, g, hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(MAXB * BS)
    valid = pos[None] < lens[:, None]           # (B, S)
    scores = jnp.where(valid[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs.astype(v.dtype), v)
    return out.reshape(B, nq, hd)


def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array,
                  q_offset: int = 0, prefix_pad: int = 0,
                  q_valid: int = 0) -> jax.Array:
    """Causal attention oracle. q: (bh, s, hd); k/v: (bh, P + s, hd)
    where P = prefix_pad (or q_offset when prefix_pad == 0).

    q_offset > 0 = chunked/suffix prefill: the queries sit at absolute
    positions q_offset..q_offset+s-1 of the kv sequence (prefix-KV
    reuse). With ``prefix_pad`` > 0 the leading prefix region of k/v is
    right-padded to prefix_pad rows of which only the first q_offset are
    real — padded prefix keys are masked out of every softmax (bucketed
    q_offset: one program per prefix bucket). ``q_valid`` > 0 marks how
    many leading query rows are real: padded queries attend to nothing
    and output exactly 0 (the valid-length mask that keeps bucket pads
    from ever producing attention mass)."""
    bh, s, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqd,bkd->bqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    qpos = q_offset + jnp.arange(s)
    kj = jnp.arange(sk)
    if prefix_pad:
        is_pfx = kj < prefix_pad
        kpos = jnp.where(is_pfx, kj, q_offset + (kj - prefix_pad))
        kvalid = ~is_pfx | (kj < q_offset)
        mask = kvalid[None, :] & (kpos[None, :] <= qpos[:, None])
    else:
        mask = kj[None, :] <= qpos[:, None]
    if q_valid:
        mask = mask & (jnp.arange(s) < q_valid)[:, None]
    scores = jnp.where(mask[None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    # fully-masked (padded) query rows: exactly zero output, matching
    # the kernel's zero accumulator, not softmax's uniform fallback
    probs = probs * mask[None].astype(probs.dtype)
    return jnp.einsum("bqk,bkd->bqd", probs.astype(v.dtype), v)
