"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def kv_gather(storage: jax.Array, idx: jax.Array) -> jax.Array:
    """storage: (L, NB, BS, W); idx: (n,) int32 -> (L, n*BS, W)."""
    g = jnp.take(storage, idx, axis=1)
    L, n, bs, w = g.shape
    return g.reshape(L, n * bs, w)


def kv_scatter(storage: jax.Array, buf: jax.Array,
               idx: jax.Array) -> jax.Array:
    """storage: (L, NB, BS, W); buf: (L, n*BS, W); idx: (n,) -> storage'."""
    L, t, w = buf.shape
    n = idx.shape[0]
    bs = storage.shape[2]
    return storage.at[:, idx].set(buf.reshape(L, n, bs, w))


def paged_attention(q: jax.Array, kv_pages: jax.Array,
                    block_table: jax.Array, lens: jax.Array) -> jax.Array:
    """Decode attention over a paged KV pool (one layer).

    q: (B, nq, hd); kv_pages: (NB, BS, 2*kv_dim); block_table: (B, MAXB)
    int32 (-1 padded); lens: (B,) valid token counts. Returns (B, nq, hd).
    """
    B, nq, hd = q.shape
    NB, BS, W = kv_pages.shape
    kvd = W // 2
    nkv = kvd // hd
    g = nq // nkv
    MAXB = block_table.shape[1]
    scale = 1.0 / math.sqrt(hd)

    bt = jnp.clip(block_table, 0, NB - 1)
    gathered = kv_pages[bt]                     # (B, MAXB, BS, W)
    kv = gathered.reshape(B, MAXB * BS, W)
    k = kv[..., :kvd].reshape(B, MAXB * BS, nkv, hd)
    v = kv[..., kvd:].reshape(B, MAXB * BS, nkv, hd)
    qg = q.reshape(B, nkv, g, hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(MAXB * BS)
    valid = pos[None] < lens[:, None]           # (B, S)
    scores = jnp.where(valid[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs.astype(v.dtype), v)
    return out.reshape(B, nq, hd)


def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array,
                  q_offset: int = 0) -> jax.Array:
    """Causal attention oracle. q: (bh, s, hd); k/v: (bh, q_offset+s, hd).

    q_offset > 0 = chunked/suffix prefill: the queries are the LAST s
    positions of the kv sequence (prefix-KV reuse)."""
    bh, s, hd = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqd,bkd->bqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    qpos = q_offset + jnp.arange(s)
    kpos = jnp.arange(sk)
    mask = kpos[None, :] <= qpos[:, None]
    scores = jnp.where(mask[None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", probs.astype(v.dtype), v)
