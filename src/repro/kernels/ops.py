"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python with the same BlockSpec semantics; on TPU they compile
natively. ``REPRO_KERNELS=ref`` forces the pure-jnp oracles (used by the
engine's fallback path and for differential testing).
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ref
from repro.kernels.kv_gather import kv_gather_pallas
from repro.kernels.kv_scatter import kv_scatter_pallas
from repro.kernels.flash_prefill import flash_prefill_pallas
from repro.kernels.paged_attention import paged_attention_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _use_ref() -> bool:
    return os.environ.get("REPRO_KERNELS", "pallas") == "ref"


@partial(jax.jit, static_argnames=())
def _kv_gather_ref(storage, idx):
    return ref.kv_gather(storage, idx)


# kv_gather / kv_scatter are pure memory movement: the ref path is
# bitwise-identical to the kernels, so off-TPU (where the Pallas kernel
# would run through the grid interpreter — ~1s per transfer on the
# serving hot path, even jitted) the jitted ref implementation IS the
# data path. The kernels stay differentially tested against the same
# ref in tests/test_kernels.py and compile natively on TPU.
_kv_scatter_ref = jax.jit(ref.kv_scatter)


def kv_gather(storage: jax.Array, idx: jax.Array) -> jax.Array:
    if _use_ref() or _interpret():
        return _kv_gather_ref(storage, idx)
    return kv_gather_pallas(storage, idx, interpret=False)


def kv_scatter(storage: jax.Array, buf: jax.Array,
               idx: jax.Array) -> jax.Array:
    if _use_ref() or _interpret():
        return _kv_scatter_ref(storage, buf.astype(storage.dtype), idx)
    return kv_scatter_pallas(storage, buf, idx, interpret=False)


# Per-layer-triggered transfer (paper Fig. 10): move ONE layer's stripe
# of the linearized buffer while later layers are still prefilling. The
# layer slice is taken OUTSIDE the kernel (a zero-copy lax.slice on the
# leading axis), so the same gather/scatter kernels serve both the
# whole-buffer and per-layer paths — on TPU they compile natively over
# the single-layer view, off-TPU they route to the jitted bitwise ref.

def kv_gather_layer(storage: jax.Array, idx: jax.Array,
                    layer: int) -> jax.Array:
    """storage: (L, NB, BS, W) -> (n*BS, W) stripe of ``layer``."""
    return kv_gather(lax.slice_in_dim(storage, layer, layer + 1, axis=0),
                     idx)[0]


def kv_scatter_layer(storage: jax.Array, buf: jax.Array, idx: jax.Array,
                     layer: int) -> jax.Array:
    """Scatter one layer's (n*BS, W) stripe back into paged storage."""
    row = kv_scatter(lax.slice_in_dim(storage, layer, layer + 1, axis=0),
                     buf[None], idx)
    return lax.dynamic_update_slice_in_dim(storage, row, layer, axis=0)


# Decode attention routes like kv_gather/kv_scatter: off-TPU the jitted
# pure-jnp ref IS the data path (the Pallas grid interpreter re-traces
# the whole page loop per call on the decode hot loop), on TPU the
# kernel compiles natively. ``paged_attention_inline`` is the traceable
# form for use INSIDE an enclosing jit (the fused decode step): same
# math, no nested jit boundary — so the eager per-layer loop and the
# fused step share bitwise-identical attention on every backend.
_paged_attention_ref = jax.jit(ref.paged_attention)


def paged_attention_inline(q: jax.Array, kv_pages: jax.Array,
                           block_table: jax.Array,
                           lens: jax.Array) -> jax.Array:
    if _use_ref() or _interpret():
        return ref.paged_attention(q, kv_pages, block_table, lens)
    return paged_attention_pallas(q, kv_pages, block_table, lens,
                                  interpret=False)


def paged_attention(q: jax.Array, kv_pages: jax.Array,
                    block_table: jax.Array, lens: jax.Array) -> jax.Array:
    if _use_ref() or _interpret():
        return _paged_attention_ref(q, kv_pages, block_table, lens)
    return paged_attention_pallas(q, kv_pages, block_table, lens,
                                  interpret=False)


_flash_prefill_ref = jax.jit(ref.flash_prefill,
                             static_argnames=("q_offset", "prefix_pad",
                                              "q_valid"))


def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array,
                  q_offset: int = 0, prefix_pad: int = 0,
                  q_valid: int = 0) -> jax.Array:
    """q_offset > 0: suffix-only (chunked) prefill against a reused
    prefix KVCache — k/v cover prefix_pad + s positions (prefix_pad
    defaults to q_offset; larger = a right-padded prefix bucket whose
    padded keys are masked). q_valid > 0: only the first q_valid query
    rows are real; padded queries attend to nothing (output 0)."""
    if _use_ref():
        return _flash_prefill_ref(q, k, v, q_offset=q_offset,
                                  prefix_pad=prefix_pad, q_valid=q_valid)
    return flash_prefill_pallas(q, k, v, interpret=_interpret(),
                                q_offset=q_offset, prefix_pad=prefix_pad,
                                q_valid=q_valid)
