"""Pallas kernel: causal flash attention for prefill (one head-batch tile).

The prefill hot spot (T_p drives Eq.1/Eq.2). Flash pattern on TPU: grid =
(batch*heads, q_tiles, kv_tiles) with kv innermost; online-softmax state
(m, l, acc) in VMEM scratch persists across the kv dimension; each step
multiplies a (q_tile, hd)x(hd, kv_tile) score block on the MXU, masks
causally, and accumulates. q_tile/kv_tile default 128 — lane-aligned and
small enough that q-tile + kv-tile + acc stay well under VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            q_tile: int, kv_tile: int, kv_tiles: int, scale: float,
            q_offset: int, prefix_pad: int, q_valid: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip fully-masked kv tiles: a tile does work iff it holds a REAL
    # prefix key (row < q_offset) or a suffix key whose first relative
    # index does not exceed the tile's last (suffix-relative) query row
    ts = kj * kv_tile
    last_q = (qi + 1) * q_tile - 1

    @pl.when((ts < q_offset)
             | ((ts + kv_tile > prefix_pad)
                & (jnp.maximum(ts, prefix_pad) - prefix_pad <= last_q)))
    def _work():
        q = q_ref[0].astype(jnp.float32)          # (q_tile, hd)
        k = k_ref[0].astype(jnp.float32)          # (kv_tile, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T) * scale               # (q_tile, kv_tile)
        qrel = qi * q_tile + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 0)                # suffix-relative q row
        qpos = q_offset + qrel
        kr = ts + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)                # key ROW index
        # prefix region rows sit at their own position and are real only
        # below q_offset; suffix rows continue at q_offset (when
        # prefix_pad == q_offset this reduces to kpos == kr, all valid)
        is_pfx = kr < prefix_pad
        kpos = jnp.where(is_pfx, kr, q_offset + (kr - prefix_pad))
        mask = (~is_pfx | (kr < q_offset)) & (kpos <= qpos)
        if q_valid:
            mask &= qrel < q_valid
        s = jnp.where(mask, s, -1e30)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(p, v)
        m_ref[...] = m_cur

    @pl.when(kj == kv_tiles - 1)
    def _finish():
        # fully-masked (padded) query rows have l == 0: the clamp makes
        # their output exactly 0 — padded queries attend to nothing
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_prefill_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         q_tile: int = 128, kv_tile: int = 128,
                         interpret: bool = True,
                         q_offset: int = 0, prefix_pad: int = 0,
                         q_valid: int = 0) -> jax.Array:
    """Causal attention. q: (bh, s, hd); k/v: (bh, P + s, hd) with heads
    flattened into the leading dim (GQA expansion happens in the
    wrapper) and P = prefix_pad (or q_offset when prefix_pad == 0).
    Returns (bh, s, hd).

    q_offset > 0 = chunked/suffix prefill against a reused prefix
    KVCache: the queries sit at absolute positions q_offset.. of the kv
    sequence; kv tiles left of the causal frontier still stream through
    the same online-softmax state. With prefix_pad > 0 the prefix
    region is right-padded to a static bucket and only its first
    q_offset keys are real (padded prefix keys masked from every
    softmax). q_valid > 0 = only the first q_valid query rows are real;
    padded queries attend to nothing and output exactly 0.
    """
    bh, s, hd = q.shape
    sk = k.shape[1]
    p_pad = prefix_pad if prefix_pad else q_offset
    assert p_pad >= q_offset, (prefix_pad, q_offset)
    assert sk == p_pad + s, (sk, p_pad, s)
    assert s % q_tile == 0 and sk % kv_tile == 0, (s, sk, q_tile, kv_tile)
    q_tiles = s // q_tile
    kv_tiles = sk // kv_tile
    scale = 1.0 / math.sqrt(hd)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(bh, q_tiles, kv_tiles),
        in_specs=[
            pl.BlockSpec((1, q_tile, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kv_tile, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kv_tile, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_tile, hd), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((q_tile,), jnp.float32),
            pltpu.VMEM((q_tile,), jnp.float32),
            pltpu.VMEM((q_tile, hd), jnp.float32),
        ],
    )
    kern = functools.partial(_kernel, q_tile=q_tile, kv_tile=kv_tile,
                             kv_tiles=kv_tiles, scale=scale,
                             q_offset=q_offset, prefix_pad=p_pad,
                             q_valid=q_valid)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
