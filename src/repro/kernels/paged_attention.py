"""Pallas kernel: decode attention over a paged KV pool (one layer).

Flash-decoding over pages: grid = (batch, max_blocks); the block table and
lengths ride in scalar-prefetch SMEM and drive the KV page index_map; the
online-softmax state (m, l, acc) lives in VMEM scratch that persists across
the page dimension of the grid. Each step DMAs one (block_size, 2*kv_dim)
page into VMEM — the working set is q-tile + one page, far under the 16MB
VMEM budget; hd=64/128 keeps the MXU matmuls lane-aligned.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(bt_ref, lens_ref, q_ref, kv_ref, o_ref, m_ref, l_ref, acc_ref,
            *, bs: int, nkv: int, g: int, hd: int, max_blocks: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kvd = nkv * hd
    page = kv_ref[0]                       # (bs, 2*kvd)
    k = page[:, :kvd].reshape(bs, nkv, hd)
    v = page[:, kvd:].reshape(bs, nkv, hd)
    q = q_ref[0].reshape(nkv, g, hd)       # (nkv, g, hd)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("kgd,skd->kgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale   # (nkv, g, bs)

    valid_here = lens_ref[b] - j * bs      # tokens valid in this page
    tok = jax.lax.broadcasted_iota(jnp.int32, (nkv, g, bs), 2)
    live = (tok < valid_here) & (bt_ref[b, j] >= 0)
    s = jnp.where(live, s, -1e30)

    m_prev = m_ref[...]                    # (nkv, g)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[..., None])      # (nkv, g, bs)
    p = jnp.where(live, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * alpha[..., None]
                    + jnp.einsum("kgs,skd->kgd", p,
                                 v.astype(jnp.float32)))
    m_ref[...] = m_cur

    @pl.when(j == max_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = (acc_ref[...] / denom).reshape(
            nkv * g, hd).astype(o_ref.dtype)


def paged_attention_pallas(q: jax.Array, kv_pages: jax.Array,
                           block_table: jax.Array, lens: jax.Array, *,
                           interpret: bool = True) -> jax.Array:
    """q: (B, nq, hd); kv_pages: (NB, BS, 2*kvd); block_table: (B, MAXB)
    int32 (-1 pad); lens: (B,). Returns (B, nq, hd)."""
    B, nq, hd = q.shape
    NB, BS, W = kv_pages.shape
    kvd = W // 2
    nkv = kvd // hd
    g = nq // nkv
    MAXB = block_table.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,             # block_table, lens
        grid=(B, MAXB),
        in_specs=[
            pl.BlockSpec((1, nq, hd), lambda b, j, bt, ln: (b, 0, 0)),
            pl.BlockSpec(
                (1, BS, W),
                lambda b, j, bt, ln: (jnp.maximum(bt[b, j], 0), 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, nq, hd), lambda b, j, bt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((nkv, g), jnp.float32),
            pltpu.VMEM((nkv, g), jnp.float32),
            pltpu.VMEM((nkv, g, hd), jnp.float32),
        ],
    )
    kern = functools.partial(_kernel, bs=BS, nkv=nkv, g=g, hd=hd,
                             max_blocks=MAXB)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nq, hd), q.dtype),
        interpret=interpret,
    )(block_table, lens, q, kv_pages)
