"""Pallas kernel: RecvScatter — restore discrete KV blocks from bytes.

The C3 receiver hot path (paper §3.6): the contiguous buffer that arrived
over RDMA is scattered back into the receiver's paged pool at the
destination block table. Implemented as an *operator* (the paper's
flexibility option): the pool buffer is donated via input_output_aliases
so untouched pages keep their content and touched pages are overwritten
in place, without interrupting other operators in the stream.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, pool_ref, buf_ref, out_ref):
    out_ref[0] = buf_ref[...]


def kv_scatter_pallas(storage: jax.Array, buf: jax.Array, idx: jax.Array, *,
                      interpret: bool = True) -> jax.Array:
    """storage: (L, NB, BS, W); buf: (L, n*BS, W); idx: (n,) int32.
    Returns the updated pool (same buffer, donated)."""
    L, NB, BS, W = storage.shape
    n = idx.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(L, n),
        in_specs=[
            # the pool rides through untouched via aliasing; present it to
            # the kernel so the alias has a position in the operand list
            pl.BlockSpec((1, 1, BS, W),
                         lambda l, i, idx_ref: (l, idx_ref[i], 0, 0)),
            pl.BlockSpec((1, BS, W), lambda l, i, idx_ref: (l, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, BS, W),
                               lambda l, i, idx_ref: (l, idx_ref[i], 0, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(storage.shape, storage.dtype),
        input_output_aliases={1: 0},   # pool operand aliases the output
        interpret=interpret,
    )(idx, storage, buf.astype(storage.dtype))
