from repro.distribution.sharding import (  # noqa: F401
    ShardingRules, batch_axes_for, make_shardings, spec_from_axes)
