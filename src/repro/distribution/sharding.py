"""Logical-axis -> mesh-axis sharding rules with divisibility fallback.

JAX rejects uneven input shardings (verified empirically), so every rule
application checks divisibility and drops mesh axes that do not divide the
dimension. Within one parameter, a mesh axis is used at most once
(PartitionSpec constraint): dims are resolved left-to-right and later dims
skip already-claimed axes.

Modes
-----
train / prefill: 2D FSDP x TP. `embed`-like dims shard over (pod, data),
    ff/heads/vocab over `model`; batch over (pod, data).
decode (baseline): same weight sharding (naive port of the training layout —
    the paper-faithful baseline for §Perf); cache batch over (pod, data) with
    seq-dim fallback for batch=1, kv_dim over `model`.
decode_opt (beyond-paper): weight-stationary decode — weights keep their 2D
    sharding but activations are resharded instead of weights being gathered:
    realized by sharding `embed` on `model`-adjacent axes so GSPMD reduces
    activations (small at decode) rather than all-gathering weights.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Tree = Any

AxisRules = Dict[Optional[str], Tuple[str, ...]]

# weights
PARAM_RULES_2D: AxisRules = {
    "embed": ("pod", "data"),
    "vocab": ("model",),
    "q_heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "d_inner": ("model",),
    "expert": ("data", "pod"),
    "layers": (),
    None: (),
}

# weight-stationary decode (§Perf hillclimb): never gather weights — keep the
# same 2D layout but ALSO shard the contracting `embed` dim over `model`'s
# complement so each einsum is local + activation reduce.
PARAM_RULES_TP: AxisRules = {
    "embed": (),
    "vocab": ("model",),
    "q_heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "d_inner": ("model",),
    "expert": ("data", "pod"),
    "layers": (),
    None: (),
}

# MoE expert-parallel over `model` (§Perf hillclimb, prefill): experts move
# to the TP axis so expert weights stay resident per device (no per-chunk
# expert-weight gathers over `data`); the per-expert ffn dim is small
# (1408) and lives replicated within the expert row.
PARAM_RULES_EP = dict(PARAM_RULES_2D)
PARAM_RULES_EP["expert"] = ("model",)
PARAM_RULES_EP["ff"] = ()

CACHE_RULES: AxisRules = {
    "layers": (),
    "batch": ("pod", "data"),
    "cache_seq": ("data", "pod"),
    "kv_heads": ("model",),
    "d_inner": ("model",),
    None: (),
}

# decode hillclimb iteration 2: shard the cache SEQUENCE over `model`
# (flash-decoding style) — kv_dim-sharding splits GQA heads (8 kv heads
# cannot shard 16 ways), forcing GSPMD to all-gather the whole cache per
# step; seq-sharding keeps cache reads local and reduces score tiles.
CACHE_RULES_SEQ: AxisRules = {
    "layers": (),
    "batch": ("pod", "data"),
    "cache_seq": ("model",),
    "kv_heads": (),
    "d_inner": ("model",),
    None: (),
}

# decode hillclimb iteration 3: REPLICATE the KV cache over `model` and
# shard only the Q heads. When kv_heads < model-degree neither kv_dim- nor
# seq-sharding can avoid gathers (measured: 5.5GB resp. 44GB per step);
# GQA's whole point is that the KV cache is small — holding it replicated
# per TP rank removes every attention collective.
CACHE_RULES_REPL: AxisRules = {
    "layers": (),
    "batch": ("pod", "data"),
    "cache_seq": (),
    "kv_heads": (),
    "d_inner": ("model",),
    None: (),
}

BATCH_RULES: AxisRules = {
    "batch": ("pod", "data"),
    "seq": (),
    "embed": (),
    None: (),
}


@dataclass(frozen=True)
class ShardingRules:
    params: AxisRules
    cache: AxisRules
    batch: AxisRules

    @staticmethod
    def for_mode(mode: str) -> "ShardingRules":
        if mode in ("train", "prefill", "decode"):
            return ShardingRules(PARAM_RULES_2D, CACHE_RULES, BATCH_RULES)
        if mode == "decode_opt":
            return ShardingRules(PARAM_RULES_TP, CACHE_RULES, BATCH_RULES)
        if mode == "prefill_ep":
            return ShardingRules(PARAM_RULES_EP, CACHE_RULES, BATCH_RULES)
        if mode == "decode_opt2":
            return ShardingRules(PARAM_RULES_TP, CACHE_RULES_SEQ,
                                 BATCH_RULES)
        if mode == "decode_opt3":
            return ShardingRules(PARAM_RULES_TP, CACHE_RULES_REPL,
                                 BATCH_RULES)
        raise ValueError(mode)


def spec_from_axes(axes: Sequence[Optional[str]], shape: Sequence[int],
                   mesh: Mesh, rules: AxisRules) -> P:
    used = set()
    parts = []
    for dim, ax in zip(shape, axes):
        cand = rules.get(ax, ())
        got = []
        prod = 1
        for a in cand:
            if a in used or a not in mesh.shape:
                continue
            if dim % (prod * mesh.shape[a]) == 0:
                got.append(a)
                prod *= mesh.shape[a]
                used.add(a)
        if not got:
            parts.append(None)
        elif len(got) == 1:
            parts.append(got[0])
        else:
            parts.append(tuple(got))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def make_shardings(axes_tree: Tree, shapes_tree: Tree, mesh: Mesh,
                   rules: AxisRules) -> Tree:
    """axes_tree: tree of axis-name tuples; shapes_tree: matching tree of
    ShapeDtypeStruct (or anything with .shape)."""
    def mk(axes, sds):
        return NamedSharding(mesh, spec_from_axes(axes, sds.shape, mesh, rules))
    return jax.tree.map(mk, axes_tree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def batch_axes_for(batch_tree: Tree) -> Tree:
    """Logical axes for an input batch dict: dim0=batch, rest unsharded
    (token/label/embed/frame tensors)."""
    def f(x):
        nd = len(x.shape)
        return ("batch",) + (None,) * (nd - 1) if nd else ()
    return jax.tree.map(f, batch_tree)
