"""Activation-sharding context.

Modeling code calls ``constrain(x, logical_axes)`` at key activation
boundaries (post-embedding, block outputs, loss chunks). Under a
``sharding_ctx(mesh)`` — entered by the step builders when a mesh is
supplied — this becomes ``with_sharding_constraint`` with the logical axes
resolved by the divisibility-aware rules; with no context it is a no-op, so
single-device CPU tests and the real serving engine run unchanged.

Without these constraints GSPMD propagation can (and does — observed on the
whisper train lowering) replicate the whole loss computation when the vocab
dim is not shardable, inflating per-device temp memory by the data-parallel
factor.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding

from repro.distribution.sharding import AxisRules, spec_from_axes

ACT_RULES: AxisRules = {
    "batch": ("pod", "data"),
    # sequence-parallel residual stream at layer boundaries (Megatron-SP
    # analog). MEASURED (see EXPERIMENTS.md §Perf): seq-sharding the carry
    # cuts the remat stack 16x but triggers a 4.6x all-gather storm under
    # GSPMD (re-gather per use, 164s vs 35s collective term on 110B train);
    # gradient-accumulation microbatching achieves the memory goal without
    # it, so the default is OFF. Kept as a switchable rule for the perf log.
    "seq_act": (),
    "q_heads_act": ("model",),
    "vocab": ("model",),
    "q_heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "d_inner": ("model",),
    "cache_seq": ("data", "pod"),
    "embed": (),
    # MoE dispatch buffers: unmapped by default (propagation decides);
    # the expert-parallel act rules pin them to the canonical EP layout.
    "expert_act": (),
    "cap_act": (),
    None: (),
}

# expert-parallel activation rules (prefill_ep / train_ep hillclimb modes):
# dispatch buffers (E, C, d) live expert->model, capacity->data, d local —
# expert matmuls become fully device-local; only the token<->capacity
# resharding (an all-to-all) moves data.
ACT_RULES_EP: AxisRules = dict(ACT_RULES)
ACT_RULES_EP["expert_act"] = ("model",)
ACT_RULES_EP["cap_act"] = ("data", "pod")

_tls = threading.local()


@contextmanager
def sharding_ctx(mesh, rules: Optional[AxisRules] = None):
    prev = getattr(_tls, "ctx", None)
    _tls.ctx = (mesh, rules or ACT_RULES) if mesh is not None else None
    try:
        yield
    finally:
        _tls.ctx = prev


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    ctx = getattr(_tls, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = spec_from_axes(axes, x.shape, mesh, rules)
    if not any(p is not None for p in spec):
        return x  # nothing resolved: leave placement to propagation
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
