"""Metadata store (the paper's Zookeeper role, §3.2).

Holds the service -> scenario -> group -> instance -> RoCE-IP map, health
reports, and decode metadata pushed to prefills. Logical (pod, chip)
coordinates stand in for RoCE IPs (DESIGN.md §3).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple


@dataclass
class InstanceMeta:
    iid: str
    role: str                     # "P" | "D" | "" (stateless container)
    group: str
    roce_ips: Tuple[str, ...]     # one per device
    healthy: bool = True
    last_report: float = 0.0


class MetaStore:
    def __init__(self, health_timeout_s: float = 60.0):
        # per-store health timeout: a node silent for longer than this is
        # reported unhealthy. The serving frontend threads its EVENT
        # clock's timeout here (virtual seconds), so ejection math and
        # heartbeats share one timescale — the old hard-coded wall-clock
        # 60.0 was disconnected from the virtual timeline.
        self.health_timeout_s = float(health_timeout_s)
        self.instances: Dict[str, InstanceMeta] = {}
        self.groups: Dict[str, Dict[str, List[str]]] = {}   # gid -> {"P": [...], "D": [...]}
        self.group_scenario: Dict[str, Optional[str]] = {}  # gid -> scenario
        self._ip_counter = itertools.count()
        self.events: List[Tuple[float, str]] = []   # audit log (windowed)
        self.n_events = 0                           # monotonic count

    def _audit(self, t: float, msg: str):
        self.events.append((t, msg))
        self.n_events += 1
        if len(self.events) > 4096:                 # long-run retention
            del self.events[:-2048]

    # ------------------------------------------------------------ RoCE
    def assign_ips(self, n_devices: int) -> Tuple[str, ...]:
        base = next(self._ip_counter)
        return tuple(f"10.{base // 250}.{base % 250}.{d}"
                     for d in range(n_devices))

    # ----------------------------------------------------------- groups
    def register_group(self, gid: str, scenario: Optional[str]):
        self.groups.setdefault(gid, {"P": [], "D": []})
        self.group_scenario[gid] = scenario

    def gather_instance(self, t: float, iid: str, role: str, gid: str,
                        n_devices: int = 8) -> InstanceMeta:
        """Step 1 of the setup workflow: collect RoCE IPs in device order."""
        meta = InstanceMeta(iid, role, gid, self.assign_ips(n_devices),
                            last_report=t)
        self.instances[iid] = meta
        self.groups.setdefault(gid, {"P": [], "D": []})
        if role in ("P", "D"):
            self.groups[gid][role].append(iid)
        self._audit(t, f"gather {iid} role={role} group={gid}")
        return meta

    def collection_complete(self, gid: str, expected: int) -> bool:
        g = self.groups.get(gid, {"P": [], "D": []})
        return len(g["P"]) + len(g["D"]) >= expected

    def remove_instance(self, t: float, iid: str):
        """Logical removal — no further requests are forwarded (§3.4)."""
        meta = self.instances.pop(iid, None)
        if meta and meta.group in self.groups and meta.role in ("P", "D"):
            lst = self.groups[meta.group][meta.role]
            if iid in lst:
                lst.remove(iid)
        self._audit(t, f"remove {iid}")

    def group_members(self, gid: str, role: str) -> List[str]:
        return list(self.groups.get(gid, {}).get(role, []))

    # ----------------------------------------------------------- health
    def health_report(self, t: float, iid: str, healthy: bool = True):
        m = self.instances.get(iid)
        if m is not None:
            m.healthy = healthy
            m.last_report = t

    def unhealthy(self, t: float, timeout: Optional[float] = None
                  ) -> List[str]:
        """Instances flagged unhealthy or silent past the store's
        timeout (override per call with ``timeout``)."""
        if timeout is None:
            timeout = self.health_timeout_s
        return [iid for iid, m in self.instances.items()
                if not m.healthy or t - m.last_report > timeout]

    def silent_since(self, iid: str) -> Optional[float]:
        """Last report time for ``iid``, or None if unregistered — the
        fault controller's input for exact-deadline ejection
        (eject at last_report + health_timeout_s)."""
        m = self.instances.get(iid)
        return None if m is None else m.last_report
