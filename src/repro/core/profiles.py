"""Serving cost profiles derived from a ModelConfig + chip constants.

The discrete-event simulator needs TTFT/TPOT/transfer costs per instance.
They are derived from the same roofline arithmetic the dry-run uses:
prefill is compute-bound (2·N·tokens / instance FLOPs), decode is
memory-bound (params + cache bytes / HBM bw), transfer time comes from
KV bytes over the LinkModel.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.models.config import ATTN, ModelConfig

# per-instance hardware (8 chips like the paper's Atlas instances)
CHIPS_PER_INSTANCE = 8
PEAK_FLOPS = 197e12 * CHIPS_PER_INSTANCE * 0.45   # 45% prefill MFU
HBM_BW = 819e9 * CHIPS_PER_INSTANCE * 0.7


@dataclass(frozen=True)
class ServingProfile:
    name: str
    kv_bytes_per_token: int
    prefill_tok_rate: float      # tokens/s at reference batch
    prefill_fixed: float         # per-batch fixed overhead (s)
    tpot_base: float             # decode iteration floor (s)
    tpot_per_req: float          # added per concurrent request (s)
    params_bytes: int
    prefix_reuse_eff: float = 0.95   # fraction of hit tokens skipped

    def ttft(self, batch_tokens: int, hit_tokens: int = 0) -> float:
        eff = batch_tokens - self.prefix_reuse_eff * hit_tokens
        return self.prefill_fixed + max(eff, 0.0) / self.prefill_tok_rate

    def tpot(self, concurrent: int) -> float:
        return self.tpot_base + self.tpot_per_req * concurrent


def profile_for(cfg: ModelConfig) -> ServingProfile:
    n_attn = sum(1 for k in cfg.layer_kinds() if k == ATTN)
    kv_bpt = 2 * cfg.kv_dim * n_attn * 2          # K+V, bf16
    n = cfg.param_count(active_only=True)
    params_bytes = cfg.param_count() * 2
    tok_rate = PEAK_FLOPS / (2.0 * n)             # prefill tokens/s
    # decode iteration: weights + avg cache traffic per token
    tpot_base = params_bytes / CHIPS_PER_INSTANCE / HBM_BW * CHIPS_PER_INSTANCE
    tpot_base = params_bytes / HBM_BW
    tpot_per_req = kv_bpt * 2048 / HBM_BW         # ~2k ctx cache read
    return ServingProfile(
        name=cfg.name, kv_bytes_per_token=kv_bpt,
        prefill_tok_rate=tok_rate, prefill_fixed=0.015,
        tpot_base=tpot_base, tpot_per_req=tpot_per_req,
        params_bytes=params_bytes)
