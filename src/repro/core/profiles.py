"""Serving cost profiles derived from a ModelConfig + chip constants.

The discrete-event simulator needs TTFT/TPOT/transfer costs per instance.
They are derived from the same roofline arithmetic the dry-run uses:
prefill is compute-bound (2·N·tokens / instance FLOPs), decode is
memory-bound (params + cache bytes / HBM bw), transfer time comes from
KV bytes over the LinkModel.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.models.config import ATTN, ModelConfig

# per-instance hardware (8 chips like the paper's Atlas instances)
CHIPS_PER_INSTANCE = 8
PEAK_FLOPS = 197e12 * CHIPS_PER_INSTANCE * 0.45   # 45% prefill MFU
HBM_BW = 819e9 * CHIPS_PER_INSTANCE * 0.7


@dataclass(frozen=True)
class ServingProfile:
    name: str
    kv_bytes_per_token: int
    prefill_tok_rate: float      # tokens/s at reference batch
    prefill_fixed: float         # per-batch fixed overhead (s)
    tpot_base: float             # decode iteration floor (s)
    tpot_per_req: float          # added per concurrent request (s)
    params_bytes: int
    prefix_reuse_eff: float = 0.95   # fraction of hit tokens skipped

    def ttft(self, batch_tokens: int, hit_tokens: int = 0) -> float:
        eff = batch_tokens - self.prefix_reuse_eff * hit_tokens
        return self.prefill_fixed + max(eff, 0.0) / self.prefill_tok_rate

    def tpot(self, concurrent: int) -> float:
        return self.tpot_base + self.tpot_per_req * concurrent


@dataclass(frozen=True)
class NodeClass:
    """One hardware class in the shared autoscaler node pool.

    The real engines execute the same compute regardless of class (token
    streams are class-invariant); a class only scales the VIRTUAL
    service time its node charges the event clock — prefill-heavy nodes
    run prefill batches faster and decode steps slower, decode-heavy
    the inverse. ``role_bias`` steers the pool's lease choice: the
    autoscaler prefers a prefill-heavy node when growing the P side of
    a group, falling back to balanced then off-bias classes when the
    preferred inventory is exhausted. ``provision_level`` picks the
    ``core.mlops.substitute_ready_delay`` timeline a provisioning event
    pays before the node takes traffic (one stateless container:
    connect + model load + health)."""
    name: str
    role_bias: str = ""              # "P" | "D" | "" (no preference)
    prefill_scale: float = 1.0       # service-time multiplier (<1 faster)
    decode_scale: float = 1.0
    provision_level: str = "node_replace"


BALANCED = NodeClass("balanced")
PREFILL_HEAVY = NodeClass("prefill-heavy", role_bias="P",
                          prefill_scale=0.6, decode_scale=1.5)
DECODE_HEAVY = NodeClass("decode-heavy", role_bias="D",
                         prefill_scale=1.5, decode_scale=0.6)

NODE_CLASSES = {c.name: c for c in (BALANCED, PREFILL_HEAVY, DECODE_HEAVY)}


def profile_for(cfg: ModelConfig) -> ServingProfile:
    n_attn = sum(1 for k in cfg.layer_kinds() if k == ATTN)
    kv_bpt = 2 * cfg.kv_dim * n_attn * 2          # K+V, bf16
    n = cfg.param_count(active_only=True)
    params_bytes = cfg.param_count() * 2
    tok_rate = PEAK_FLOPS / (2.0 * n)             # prefill tokens/s
    # decode iteration: weights + avg cache traffic per token
    tpot_base = params_bytes / CHIPS_PER_INSTANCE / HBM_BW * CHIPS_PER_INSTANCE
    tpot_base = params_bytes / HBM_BW
    tpot_per_req = kv_bpt * 2048 / HBM_BW         # ~2k ctx cache read
    return ServingProfile(
        name=cfg.name, kv_bytes_per_token=kv_bpt,
        prefill_tok_rate=tok_rate, prefill_fixed=0.015,
        tpot_base=tpot_base, tpot_per_req=tpot_per_req,
        params_bytes=params_bytes)
