"""Block-free D2D KVCache transfer (paper §3.6) + link timing model (§2.2.3).

Two transfer modes between a paged sender pool and a paged receiver pool:

  * block-fixed (the baseline the paper criticizes): one message per block;
    every message pays the control/confirmation overhead -> poor bandwidth
    utilization (Fig. 4).
  * block-free (P/D-Serve): the sender linearizes the request's blocks into
    ONE contiguous buffer (kernels.kv_gather), a single message moves the
    bytes, and the receiver restores discrete blocks with RecvScatter
    (kernels.kv_scatter). Per-layer triggering is supported by slicing the
    contiguous buffer at layer boundaries (offset/length arithmetic).

The LinkModel gives transfer *time*; the byte movement itself is executed
for real on the JAX buffers so tests can assert bit-exact delivery.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class LinkModel:
    """t = n_msgs * c_ctrl + bytes / bw (+ multi-hop conflict jitter)."""
    bandwidth: float = 25e9        # bytes/s effective D2D (RDMA, ~200Gb/s)
    c_ctrl: float = 30e-6          # per-message control/confirmation cost
    hops: int = 1                  # ToR only = 1; ToR+spine = 2+
    conflict_prob: float = 0.0     # chance a multi-hop transfer conflicts
    conflict_penalty: float = 0.15 # seconds added on conflict (paper: 100s of ms)

    def time(self, nbytes: int, n_msgs: int,
             rng: Optional[random.Random] = None) -> float:
        t = n_msgs * self.c_ctrl + nbytes / self.bandwidth
        if self.hops > 1 and self.conflict_prob > 0 and rng is not None:
            if rng.random() < self.conflict_prob:
                t += rng.uniform(0.3, 1.0) * self.conflict_penalty
        return t

    def utilization(self, nbytes: int, n_msgs: int) -> float:
        ideal = nbytes / self.bandwidth
        return ideal / self.time(nbytes, n_msgs)

    def per_layer_completion(self, nbytes: int, layers: int,
                             compute_s: float) -> float:
        """Finish time (relative to prefill START) of a per-layer-triggered
        transfer overlapped with layer compute (Fig. 10).

        Layer ``i`` of ``layers`` equal segments becomes sendable at
        ``compute_s * (i+1)/layers``; the link ships segments FIFO, one
        in flight at a time. This closed form is the SHARED overlap
        model: the discrete-event simulator (core.cluster_sim) and the
        real path's TransferScheduler (serving.transfer_sched) must both
        report it for an uncontended single transfer — test_transfer.py
        pins them together."""
        layers = max(1, layers)
        seg = self.time(nbytes / layers, 1)
        t = 0.0
        for i in range(layers):
            t = max(t, compute_s * (i + 1) / layers) + seg
        return t

    def per_layer_tail(self, nbytes: int, layers: int,
                       compute_s: float) -> float:
        """Residual D2D wait AFTER prefill completes under per-layer
        triggering — the part of the transfer compute could not hide."""
        return max(0.0, self.per_layer_completion(nbytes, layers, compute_s)
                   - compute_s)


def layer_slices(layers: int, nbytes: int) -> List[Tuple[int, int]]:
    """(byte_offset, byte_length) of each layer's slice of the linearized
    block-free buffer (Fig. 10 offset/length arithmetic): the sender
    gathers blocks into ONE contiguous (layers, tokens, width) buffer, so
    layer ``i`` occupies one equal contiguous stripe."""
    layers = max(1, layers)
    assert nbytes % layers == 0, (nbytes, layers)
    stride = nbytes // layers
    return [(i * stride, stride) for i in range(layers)]


@dataclass
class TransferResult:
    nbytes: int
    n_msgs: int
    time_s: float
    mode: str
    per_layer: bool = False


class KVTransferEngine:
    """Moves a request's KV blocks from a sender pool to a receiver pool.

    Pools are `repro.serving.kvcache.PagedKVPool`s sharing block geometry
    (paper: P and D use the same per-index device layout, so each transfer
    is shard-local). Timing comes from the LinkModel; data movement happens
    on the actual arrays via the gather/scatter ops so correctness is
    testable end to end.
    """

    def __init__(self, link: LinkModel = LinkModel(), *,
                 seed: int = 0):
        self.link = link
        self.rng = random.Random(seed)
        self.stats: List[TransferResult] = []

    # -------------------------------------------------------------- modes
    def transfer_block_fixed(self, src_pool, src_blocks: Sequence[int],
                             dst_pool, dst_blocks: Sequence[int]
                             ) -> TransferResult:
        """Baseline: one message per block per layer — discrete transfers
        with per-message confirmation (paper Fig. 4a)."""
        assert len(src_blocks) == len(dst_blocks)
        nbytes = 0
        n_msgs = 0
        for s, d in zip(src_blocks, dst_blocks):
            blk = src_pool.read_block(s)          # (layers, block, kv)
            dst_pool.write_block(d, blk)
            nbytes += blk.size * blk.dtype.itemsize
            n_msgs += blk.shape[0]                # one message per layer-block
        t = self.link.time(nbytes, n_msgs, self.rng)
        res = TransferResult(nbytes, n_msgs, t, "block_fixed")
        self.stats.append(res)
        return res

    def transfer_block_free(self, src_pool, src_blocks: Sequence[int],
                            dst_pool, dst_blocks: Sequence[int], *,
                            per_layer: bool = False) -> TransferResult:
        """P/D-Serve: gather blocks to ONE contiguous buffer at the sender,
        move bytes as a whole (or one message per layer when the per-layer
        trigger is enabled), RecvScatter restores blocks at the receiver."""
        assert len(src_blocks) == len(dst_blocks)
        buf = src_pool.gather_contiguous(src_blocks)   # (layers, tokens, kv)
        # "wire": a single byte-array move; offset/length per layer is
        # computable from (layer index, prompt len, kv width) — Fig. 10.
        dst_pool.scatter_contiguous(buf, dst_blocks)
        nbytes = buf.size * buf.dtype.itemsize
        n_msgs = buf.shape[0] if per_layer else 1
        t = self.link.time(nbytes, n_msgs, self.rng)
        res = TransferResult(nbytes, n_msgs, t, "block_free", per_layer)
        self.stats.append(res)
        return res

    # ---------------------------------------------------- timing-only API
    def time_only(self, nbytes: int, *, block_bytes: int, layers: int,
                  mode: str, per_layer: bool = False) -> float:
        """Transfer time without touching buffers (simulator path)."""
        if mode == "block_fixed":
            n_msgs = max(1, math.ceil(nbytes / block_bytes)) * layers
        else:
            n_msgs = layers if per_layer else 1
        return self.link.time(nbytes, n_msgs, self.rng)
