"""Block-free D2D KVCache transfer (paper §3.6) + link timing model (§2.2.3).

Two transfer modes between a paged sender pool and a paged receiver pool:

  * block-fixed (the baseline the paper criticizes): one message per block;
    every message pays the control/confirmation overhead -> poor bandwidth
    utilization (Fig. 4).
  * block-free (P/D-Serve): the sender linearizes the request's blocks into
    ONE contiguous buffer (kernels.kv_gather), a single message moves the
    bytes, and the receiver restores discrete blocks with RecvScatter
    (kernels.kv_scatter). Per-layer triggering is supported by slicing the
    contiguous buffer at layer boundaries (offset/length arithmetic).

The LinkModel gives transfer *time*; the byte movement itself is executed
for real on the JAX buffers so tests can assert bit-exact delivery.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class LinkModel:
    """t = n_msgs * c_ctrl + bytes / bw (+ multi-hop conflict jitter)."""
    bandwidth: float = 25e9        # bytes/s effective D2D (RDMA, ~200Gb/s)
    c_ctrl: float = 30e-6          # per-message control/confirmation cost
    hops: int = 1                  # ToR only = 1; ToR+spine = 2+
    conflict_prob: float = 0.0     # chance a multi-hop transfer conflicts
    conflict_penalty: float = 0.15 # seconds added on conflict (paper: 100s of ms)

    def time(self, nbytes: int, n_msgs: int,
             rng: Optional[random.Random] = None) -> float:
        t = n_msgs * self.c_ctrl + nbytes / self.bandwidth
        if self.hops > 1 and self.conflict_prob > 0 and rng is not None:
            if rng.random() < self.conflict_prob:
                t += rng.uniform(0.3, 1.0) * self.conflict_penalty
        return t

    def utilization(self, nbytes: int, n_msgs: int) -> float:
        ideal = nbytes / self.bandwidth
        return ideal / self.time(nbytes, n_msgs)


@dataclass
class TransferResult:
    nbytes: int
    n_msgs: int
    time_s: float
    mode: str
    per_layer: bool = False


class KVTransferEngine:
    """Moves a request's KV blocks from a sender pool to a receiver pool.

    Pools are `repro.serving.kvcache.PagedKVPool`s sharing block geometry
    (paper: P and D use the same per-index device layout, so each transfer
    is shard-local). Timing comes from the LinkModel; data movement happens
    on the actual arrays via the gather/scatter ops so correctness is
    testable end to end.
    """

    def __init__(self, link: LinkModel = LinkModel(), *,
                 seed: int = 0):
        self.link = link
        self.rng = random.Random(seed)
        self.stats: List[TransferResult] = []

    # -------------------------------------------------------------- modes
    def transfer_block_fixed(self, src_pool, src_blocks: Sequence[int],
                             dst_pool, dst_blocks: Sequence[int]
                             ) -> TransferResult:
        """Baseline: one message per block per layer — discrete transfers
        with per-message confirmation (paper Fig. 4a)."""
        assert len(src_blocks) == len(dst_blocks)
        nbytes = 0
        n_msgs = 0
        for s, d in zip(src_blocks, dst_blocks):
            blk = src_pool.read_block(s)          # (layers, block, kv)
            dst_pool.write_block(d, blk)
            nbytes += blk.size * blk.dtype.itemsize
            n_msgs += blk.shape[0]                # one message per layer-block
        t = self.link.time(nbytes, n_msgs, self.rng)
        res = TransferResult(nbytes, n_msgs, t, "block_fixed")
        self.stats.append(res)
        return res

    def transfer_block_free(self, src_pool, src_blocks: Sequence[int],
                            dst_pool, dst_blocks: Sequence[int], *,
                            per_layer: bool = False) -> TransferResult:
        """P/D-Serve: gather blocks to ONE contiguous buffer at the sender,
        move bytes as a whole (or one message per layer when the per-layer
        trigger is enabled), RecvScatter restores blocks at the receiver."""
        assert len(src_blocks) == len(dst_blocks)
        buf = src_pool.gather_contiguous(src_blocks)   # (layers, tokens, kv)
        # "wire": a single byte-array move; offset/length per layer is
        # computable from (layer index, prompt len, kv width) — Fig. 10.
        dst_pool.scatter_contiguous(buf, dst_blocks)
        nbytes = buf.size * buf.dtype.itemsize
        n_msgs = buf.shape[0] if per_layer else 1
        t = self.link.time(nbytes, n_msgs, self.rng)
        res = TransferResult(nbytes, n_msgs, t, "block_free", per_layer)
        self.stats.append(res)
        return res

    # ---------------------------------------------------- timing-only API
    def time_only(self, nbytes: int, *, block_bytes: int, layers: int,
                  mode: str, per_layer: bool = False) -> float:
        """Transfer time without touching buffers (simulator path)."""
        if mode == "block_fixed":
            n_msgs = max(1, math.ceil(nbytes / block_bytes)) * layers
        else:
            n_msgs = layers if per_layer else 1
        return self.link.time(nbytes, n_msgs, self.rng)
