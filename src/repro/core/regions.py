"""Multi-region organization + request routing (paper §3.7).

A cluster has multiple regions (thousands of NPUs each); P/D groups are
deployed per scenario to any region. The ELB/SLB tier load-balances across
regions; the MSG (model-service gateway) tier inside each region runs the
on-demand forwarding of §3.5. Region-level failures shift traffic to the
surviving regions without service interruption (disaster recovery).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core.cluster_sim import ClusterSim, SimConfig
from repro.core.requests import Request


@dataclass
class Region:
    name: str
    sims: Dict[str, ClusterSim] = field(default_factory=dict)  # scenario->
    healthy: bool = True

    def capacity_weight(self, scenario: str) -> float:
        sim = self.sims.get(scenario)
        if sim is None or not self.healthy:
            return 0.0
        return float(len(sim.prefills))


class ServiceRouter:
    """ELB/SLB stand-in: weighted routing of scenario traffic to regions,
    with region-failure failover. The per-region MSG behavior (rejection
    retries, SSE accounting) lives inside each ClusterSim."""

    def __init__(self, regions: Sequence[Region], *, seed: int = 0):
        self.regions = list(regions)
        self.rng = random.Random(seed)
        self.routed: Dict[str, int] = {}
        self.dropped = 0

    def route(self, req: Request) -> Optional[Region]:
        weights = [r.capacity_weight(req.scenario) for r in self.regions]
        total = sum(weights)
        if total <= 0:
            self.dropped += 1
            return None
        pick = self.rng.choices(self.regions, weights=weights)[0]
        self.routed[pick.name] = self.routed.get(pick.name, 0) + 1
        pick.sims[req.scenario].submit(req)
        return pick

    def fail_region(self, name: str):
        """Region-level failure: ELB stops routing there immediately."""
        for r in self.regions:
            if r.name == name:
                r.healthy = False

    def restore_region(self, name: str):
        for r in self.regions:
            if r.name == name:
                r.healthy = True

    # ------------------------------------------------------------ driver
    def run(self, requests: Sequence[Request], horizon: float,
            *, fail_at: Optional[float] = None,
            fail_region: str = "") -> Dict[str, float]:
        # all regions share one logical clock: interleave by running each
        # region's event loop over the same horizon; arrivals are routed
        # up front (ELB is stateless per request)
        for req in sorted(requests, key=lambda r: r.arrival):
            if fail_at is not None and req.arrival >= fail_at and fail_region:
                self.fail_region(fail_region)
            self.route(req)
        ok = fail = 0
        for r in self.regions:
            for sim in r.sims.values():
                sim.clock.run_until(horizon)
                ok += len(sim.completed)
                fail += len(sim.failed)
        total = ok + fail + self.dropped
        return {
            "completed": ok,
            "failed": fail + self.dropped,
            "success_rate": ok / total if total else 1.0,
            "throughput_rps": ok / horizon,
            "routed": dict(self.routed),
            "dropped": self.dropped,
        }
