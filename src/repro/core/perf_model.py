"""The paper's E2E performance model (§2.1) and P/D-ratio optimizer (Eq. 1).

    Phi = min(I_t, n_p b_p / T_p, n_d b_d / T_d) / (n_p + n_d)
    T_p = TTFT_bs * r_pre
    T_d = xi + TPOT_bs * G
    optimum:  n_p b_p / T_p  ≈  n_d b_d / T_d            (Eq. 1)
    gateway:  I_t ≈ n_p b_p / T_p                        (Eq. 2)
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class InstanceProfile:
    """Profiled per-instance characteristics for one scenario pattern."""
    ttft_bs: float          # prefill batch latency at batch size b_p (s)
    b_p: int                # prefill batch size
    r_pre: float            # prefix-hit speedup factor in (0, 1]
    tpot_bs: float          # decode per-token iteration latency at b_d (s)
    b_d: int                # decode batch size
    gen_tokens: float       # G: mean tokens generated
    xi: float = 0.02        # KVCache transfer time (max sub-transfer, s)

    @property
    def t_p(self) -> float:
        return self.ttft_bs * self.r_pre

    @property
    def t_d(self) -> float:
        return self.xi + self.tpot_bs * self.gen_tokens

    def prefill_capability(self, n_p: int) -> float:
        """Requests/s the prefill side sustains."""
        return n_p * self.b_p / self.t_p

    def decode_capability(self, n_d: int) -> float:
        return n_d * self.b_d / self.t_d


def throughput(profile: InstanceProfile, n_p: int, n_d: int,
               input_rps: float = math.inf) -> float:
    """Phi: average throughput per instance (the paper's cost metric)."""
    if n_p <= 0 or n_d <= 0:
        return 0.0
    cap = min(input_rps,
              profile.prefill_capability(n_p),
              profile.decode_capability(n_d))
    return cap / (n_p + n_d)


def mismatch(profile: InstanceProfile, n_p: int, n_d: int) -> float:
    """|prefill - decode| capability gap, normalized (Eq. 1 residual)."""
    p = profile.prefill_capability(n_p)
    d = profile.decode_capability(n_d)
    return abs(p - d) / max(p, d)


def optimal_ratio(profile: InstanceProfile, total: int,
                  *, min_each: int = 1) -> Tuple[int, int]:
    """Integer (n_p, n_d) with n_p + n_d == total maximizing Phi
    (equivalently minimizing the Eq. 1 mismatch at the bottleneck);
    at least `min_each` of each role (single-point-failure avoidance)."""
    best = (min_each, total - min_each)
    best_phi = -1.0
    for n_p in range(min_each, total - min_each + 1):
        n_d = total - n_p
        phi = throughput(profile, n_p, n_d)
        if phi > best_phi:
            best_phi = phi
            best = (n_p, n_d)
    return best


def continuous_ratio(profile: InstanceProfile) -> float:
    """Closed-form n_p/n_d from Eq. 1: n_p/n_d = (b_d/T_d)/(b_p/T_p)."""
    return (profile.b_d / profile.t_d) / (profile.b_p / profile.t_p)


@dataclass
class BottleneckMonitor:
    """Online detection (Fig. 12c): rising E2E with shifting T_p/E2E
    proportion hints which side to grow."""
    window: int = 200
    _e2e: List[float] = None
    _tp_frac: List[float] = None

    def __post_init__(self):
        self._e2e = []
        self._tp_frac = []

    def record(self, ttft: float, e2e: float):
        if e2e <= 0:
            return
        self._e2e.append(e2e)
        self._tp_frac.append(max(ttft, 0.0) / e2e)
        if len(self._e2e) > 2 * self.window:
            del self._e2e[: -self.window]
            del self._tp_frac[: -self.window]

    def recommendation(self) -> Optional[str]:
        """'more_prefill' | 'more_decode' | None."""
        n = len(self._e2e)
        if n < 2 * self.window:
            return None
        old_e = sum(self._e2e[: self.window]) / self.window
        new_e = sum(self._e2e[-self.window:]) / self.window
        old_f = sum(self._tp_frac[: self.window]) / self.window
        new_f = sum(self._tp_frac[-self.window:]) / self.window
        if new_e < old_e * 1.15:
            return None  # no degradation alarm
        return "more_prefill" if new_f > old_f * 1.05 else "more_decode"
