"""Discrete-event cluster simulator for paper-scale experiments.

Models the full P/D-Serve data path with the cost profiles from
`core.profiles`: gateway (on-demand rejection-based forwarding vs the
queue-status baseline), prefill instances (batching + prefix cache +
transfer-wait slots), decode instances (continuous batching + async KV
retrieval), the D2D link (block-fixed vs block-free), groups, faults.

Time is simulated seconds; the engine is a plain heapq event loop.
"""
from __future__ import annotations

import heapq
import itertools
import math
import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.prefix_cache import PrefixCache
from repro.core.profiles import ServingProfile
from repro.core.requests import Request
from repro.core.transfer import LinkModel


class SimClock:
    def __init__(self):
        self.t = 0.0
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()

    def schedule(self, dt: float, fn: Callable[[], None]):
        heapq.heappush(self._heap, (self.t + max(dt, 0.0),
                                    next(self._seq), fn))

    def run_until(self, t_end: float):
        while self._heap and self._heap[0][0] <= t_end:
            t, _, fn = heapq.heappop(self._heap)
            self.t = t
            fn()
        self.t = max(self.t, t_end)

    def run_all(self, t_cap: float = float("inf")):
        self.run_until(t_cap)


# --------------------------------------------------------------------------
@dataclass
class SimConfig:
    profile: ServingProfile
    b_p: int = 4                  # prefill batch size
    b_d: int = 16                 # decode slots
    batch_window: float = 0.02    # prefill batch collect window (s)
    hbm_prefix_budget: int = 8 << 30
    transfer_mode: str = "block_free"     # | "block_fixed"
    per_layer_transfer: bool = False
    block_tokens: int = 16                # paged block size (tokens)
    layers: int = 32
    retrieval_queue: int = 2              # async-retrieval capacity (§3.6)
    link: LinkModel = field(default_factory=LinkModel)


class SimDecode:
    def __init__(self, sim: "ClusterSim", iid: str, cfg: SimConfig):
        self.sim = sim
        self.iid = iid
        self.cfg = cfg
        self.active: Dict[int, List] = {}    # rid -> [req, tokens_left]
        self.pending_retrieval: List[Request] = []
        self._iterating = False

    # admission from prefill: async retrieval with a SMALL queue
    def can_retrieve(self) -> bool:
        return (len(self.pending_retrieval) < self.cfg.retrieval_queue
                and len(self.active) + len(self.pending_retrieval)
                < self.cfg.b_d)

    def start_retrieval(self, req: Request, on_done: Callable[[], None]):
        self.pending_retrieval.append(req)
        nbytes = req.prompt_len * self.cfg.profile.kv_bytes_per_token
        block_bytes = self.cfg.block_tokens * self.cfg.profile.kv_bytes_per_token
        if self.cfg.transfer_mode == "block_free" \
                and self.cfg.per_layer_transfer:
            # per-layer triggering (Fig. 10): only the tail the prefill
            # compute could not hide is paid after prefill-done — the
            # SAME closed-form overlap model the real path's
            # TransferScheduler reports (see tests/test_transfer.py)
            t = self.cfg.link.per_layer_tail(
                nbytes, self.cfg.layers, req.t_prefill_compute)
        else:
            t = self.sim.transfer_time(nbytes, block_bytes)
        self.sim.d2d_times.append(t)

        def done():
            self.pending_retrieval.remove(req)
            req.t_transfer_done = self.sim.clock.t
            self.active[req.rid] = [req, req.output_tokens]
            self._kick()
            on_done()

        self.sim.clock.schedule(t, done)

    def _kick(self):
        if not self._iterating and self.active:
            self._iterating = True
            self.sim.clock.schedule(self._tpot(), self._iteration)

    def _tpot(self) -> float:
        return self.cfg.profile.tpot(max(len(self.active), 1))

    def _iteration(self):
        done_rids = []
        for rid, slot in self.active.items():
            slot[1] -= 1
            if slot[1] <= 0:
                done_rids.append(rid)
        for rid in done_rids:
            req = self.active.pop(rid)[0]
            req.t_done = self.sim.clock.t
            self.sim.completed.append(req)
            self.sim.on_decode_free(self)
        if self.active:
            self.sim.clock.schedule(self._tpot(), self._iteration)
        else:
            self._iterating = False


class SimPrefill:
    """No local queue (P/D-Serve): accept iff a batch seat AND a transfer
    slot are free, else reject. Baseline mode adds a FIFO local queue."""

    def __init__(self, sim: "ClusterSim", iid: str, cfg: SimConfig, *,
                 local_queue: bool = False):
        self.sim = sim
        self.iid = iid
        self.cfg = cfg
        self.local_queue = local_queue
        self.queue: List[Request] = []
        self.forming: List[Request] = []
        self.executing = False
        self.waiting_transfer = 0            # slots held for KV hand-off
        self.prefix_cache = PrefixCache(cfg.hbm_prefix_budget,
                                        cfg.profile.kv_bytes_per_token)
        self.sse_connections = 0
        self.busy_time = 0.0
        self.healthy = True
        self._window_armed = False

    # ------------------------------------------------------------ accept
    def slots_free(self) -> int:
        return self.cfg.b_p - self.waiting_transfer - len(self.forming) \
            - (self.cfg.b_p if self.executing else 0)

    def idle(self) -> bool:
        return self.healthy and not self.executing and self.slots_free() > 0

    def offer(self, req: Request) -> bool:
        """On-demand path: gateway asks; instance accepts or rejects."""
        if not self.idle():
            return False
        self._admit(req)
        return True

    def enqueue(self, req: Request):
        """Baseline path: scheduler pushes blindly into the local queue."""
        self.queue.append(req)
        self._drain_queue()

    def _drain_queue(self):
        while self.queue and self.idle():
            self._admit(self.queue.pop(0))

    def _admit(self, req: Request):
        req.t_accept = self.sim.clock.t
        self.sse_connections += 1
        self.forming.append(req)
        if len(self.forming) >= self.cfg.b_p:
            self._execute()
        elif not self._window_armed:
            self._window_armed = True
            self.sim.clock.schedule(self.cfg.batch_window, self._window_fire)

    def _window_fire(self):
        self._window_armed = False
        if self.forming and not self.executing:
            self._execute()

    # ----------------------------------------------------------- execute
    def _execute(self):
        batch = self.forming
        self.forming = []
        self.executing = True
        total_tokens = 0
        hit_tokens = 0
        for r in batch:
            cached = self.prefix_cache.lookup(r.prefix_id, r.prefix_len)
            if cached >= r.prefix_len:
                r.prefix_hit = True
                hit_tokens += cached
            else:
                self.prefix_cache.insert(r.prefix_id, r.prefix_len)
            total_tokens += r.prompt_len
        dt = self.cfg.profile.ttft(total_tokens, hit_tokens)
        for r in batch:
            r.t_prefill_compute = dt     # per-layer overlap window
        self.busy_time += dt
        self.sim.clock.schedule(dt, lambda: self._complete(batch))

    def _complete(self, batch: List[Request]):
        self.executing = False
        t = self.sim.clock.t
        for r in batch:
            # TTFT SLO check happens when prefill finishes (early
            # intervention also counts requests that exceeded it mid-run);
            # the gateway timeout watcher may have failed it already —
            # such requests consumed this batch's compute for nothing.
            r.t_prefill_done = t
            if r.timed_out:
                self.sse_connections -= 1
                continue
            if r.ttft > r.slo_ttft:
                r.timed_out = True
                self.sse_connections -= 1
                self.sim.failed.append(r)
                continue
            self.waiting_transfer += 1
            self.sim.route_to_decode(self, r)
        self._drain_queue()
        self.sim.on_prefill_idle(self)

    def transfer_started(self, req: Request):
        self.waiting_transfer -= 1
        self.sse_connections -= 1   # hand the stream over (sim simplification)
        self._drain_queue()
        self.sim.on_prefill_idle(self)


# --------------------------------------------------------------------------
class ClusterSim:
    """One P/D group (or a mixed pool) + gateway policy + link."""

    def __init__(self, cfg: SimConfig, *, n_prefill: int, n_decode: int,
                 policy: str = "ondemand", seed: int = 0,
                 retry_candidates: int = 4):
        self.cfg = cfg
        self.clock = SimClock()
        self.rng = random.Random(seed)
        self.policy = policy
        self.retry_candidates = retry_candidates
        lq = policy == "baseline"
        self.prefills = [SimPrefill(self, f"P{i}", cfg, local_queue=lq)
                         for i in range(n_prefill)]
        self.decodes = [SimDecode(self, f"D{i}", cfg)
                        for i in range(n_decode)]
        self.gateway_queue: List[Request] = []
        self.completed: List[Request] = []
        self.failed: List[Request] = []
        self.d2d_times: List[float] = []
        self.transfer_wait: List[Request] = []   # prefill-done, no decode slot

    # ------------------------------------------------------------- link
    def transfer_time(self, nbytes: int, block_bytes: int) -> float:
        if self.cfg.transfer_mode == "block_fixed":
            n_msgs = max(1, math.ceil(nbytes / block_bytes)) * self.cfg.layers
        else:
            n_msgs = self.cfg.layers if self.cfg.per_layer_transfer else 1
        return self.cfg.link.time(nbytes, n_msgs, self.rng)

    # ---------------------------------------------------------- ingress
    def submit(self, req: Request):
        if self.policy == "baseline":
            # queue-status scheduler: shortest queue by pending tokens
            tgt = min(self.prefills,
                      key=lambda p: sum(r.prompt_len for r in p.queue)
                      + sum(r.prompt_len for r in p.forming))
            tgt.enqueue(req)
            self._arm_timeout(req, where=tgt)
        else:
            self._try_assign(req)

    def _try_assign(self, req: Request):
        # least-SSE-connections ordering, retry over top candidates (§3.5)
        cands = sorted(self.prefills, key=lambda p: p.sse_connections)
        for p in cands[: self.retry_candidates]:
            if p.offer(req):
                return
            req.rejections += 1
        # all rejected: wait AT THE GATEWAY (not in a local queue)
        if req not in self.gateway_queue:
            self.gateway_queue.append(req)
            self._arm_timeout(req, where=None)

    def _arm_timeout(self, req: Request, where):
        def check():
            if req.t_prefill_done >= 0 or req.timed_out:
                return
            waited = self.clock.t - req.arrival
            if waited >= req.slo_ttft - 1e-9:
                req.timed_out = True
                if req in self.gateway_queue:
                    self.gateway_queue.remove(req)
                # NOTE: baseline keeps dead requests in the local queue —
                # "timeout intervention during prefill execution ... wastes
                # the computing power of xPU and is actually ignored"
                # (paper §4.2): they still consume batch seats when their
                # turn comes. This waste is what collapses Fig. 14a.
                self.failed.append(req)
            else:
                # min step guards against float-rounding non-progress
                self.clock.schedule(max(req.slo_ttft - waited, 1e-6), check)

        self.clock.schedule(
            max(req.slo_ttft - (self.clock.t - req.arrival), 1e-6), check)

    # ---------------------------------------------------------- routing
    def on_prefill_idle(self, p: SimPrefill):
        if self.policy != "baseline":
            pending = [r for r in self.gateway_queue if not r.timed_out]
            for r in pending:
                if not p.idle():
                    break
                if p.offer(r):
                    self.gateway_queue.remove(r)

    def route_to_decode(self, p: SimPrefill, req: Request):
        d = self._pick_decode()
        if d is None:
            self.transfer_wait.append((p, req))
            return
        d.start_retrieval(req, lambda: None)
        p.transfer_started(req)

    def _pick_decode(self) -> Optional[SimDecode]:
        free = [d for d in self.decodes if d.can_retrieve()]
        if not free:
            return None
        return min(free, key=lambda d: len(d.active)
                   + len(d.pending_retrieval))

    def on_decode_free(self, d: SimDecode):
        while self.transfer_wait and d.can_retrieve():
            p, req = self.transfer_wait.pop(0)
            d.start_retrieval(req, lambda: None)
            p.transfer_started(req)

    # ---------------------------------------------------------- metrics
    def metrics(self, horizon: float) -> Dict[str, float]:
        done = self.completed
        n_ok = len(done)
        n_fail = len(self.failed)
        tot = n_ok + n_fail
        ttfts = sorted(r.ttft for r in done if r.t_prefill_done >= 0)
        e2es = sorted(r.e2e for r in done)

        def pct(xs, p):
            return xs[min(int(p * len(xs)), len(xs) - 1)] if xs else 0.0

        n_inst = len(self.prefills) + len(self.decodes)
        return {
            "completed": n_ok,
            "failed": n_fail,
            "success_rate": n_ok / tot if tot else 1.0,
            "throughput_rps": n_ok / horizon,
            "phi": n_ok / horizon / max(n_inst, 1),
            "ttft_p50": pct(ttfts, 0.5),
            "ttft_p99": pct(ttfts, 0.99),
            "e2e_p50": pct(e2es, 0.5),
            "e2e_p99": pct(e2es, 0.99),
            "d2d_mean": (sum(self.d2d_times) / len(self.d2d_times)
                         if self.d2d_times else 0.0),
            "prefix_hit_rate": (
                sum(p.prefix_cache.hit_rate for p in self.prefills)
                / max(len(self.prefills), 1)),
        }


def run_workload(sim: ClusterSim, requests: Sequence[Request],
                 horizon: float) -> Dict[str, float]:
    for r in requests:
        sim.clock.schedule(r.arrival - sim.clock.t,
                           (lambda rr: (lambda: sim.submit(rr)))(r))
    sim.clock.run_until(horizon)
    return sim.metrics(horizon)
