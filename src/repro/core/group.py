"""Fine-grained P/D organization on the RoCE map (paper §3.2-3.3).

A PDGroup binds a scenario to a set of prefill/decode instances via the
MetaStore, runs the setup workflow (gather IPs -> init order -> connect ->
load pre-compiled model -> health reports), and supports dynamic RoCE
(re)construction for ratio adjustment, group scaling and rolling upgrade —
all without service interruption (one group at a time).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.zookeeper import MetaStore

# timing constants for workflow simulation (paper: model loads in minutes)
T_GATHER = 2.0
T_CONNECT = 5.0
T_LOAD_SFS = 180.0
T_LOAD_SSD = 75.0
T_HEALTH = 1.0

_iid = itertools.count()


@dataclass
class WorkflowEvent:
    t: float
    step: str
    detail: str = ""


class PDGroup:
    def __init__(self, gid: str, scenario: Optional[str], meta: MetaStore,
                 *, storage: str = "ssd"):
        self.gid = gid
        self.scenario = scenario
        self.meta = meta
        self.storage = storage
        self.timeline: List[WorkflowEvent] = []
        meta.register_group(gid, scenario)

    # ------------------------------------------------------- setup (§3.2)
    def setup(self, t: float, n_prefill: int, n_decode: int) -> float:
        """Runs the 6-step workflow; returns completion time."""
        tl = self.timeline
        # 1: gather RoCE IPs per instance, report to zookeeper
        for i in range(n_prefill):
            self.meta.gather_instance(t, f"{self.gid}/P{next(_iid)}", "P",
                                      self.gid)
        for i in range(n_decode):
            self.meta.gather_instance(t, f"{self.gid}/D{next(_iid)}", "D",
                                      self.gid)
        t += T_GATHER
        tl.append(WorkflowEvent(t, "gathered",
                                f"{n_prefill}P+{n_decode}D"))
        assert self.meta.collection_complete(self.gid,
                                             n_prefill + n_decode)
        # 2: init order  3: establish connections (with verification)
        t += T_CONNECT
        tl.append(WorkflowEvent(t, "connected"))
        # 4: load pre-compiled models (role-specific)
        t += T_LOAD_SSD if self.storage == "ssd" else T_LOAD_SFS
        tl.append(WorkflowEvent(t, "model_loaded", self.storage))
        # 5: first health reports  6: zookeeper confirms, label entrances
        for iid in self.members("P") + self.members("D"):
            self.meta.health_report(t, iid)
        t += T_HEALTH
        tl.append(WorkflowEvent(t, "serving", "prefills labeled entrance"))
        return t

    def members(self, role: str) -> List[str]:
        return self.meta.group_members(self.gid, role)

    @property
    def ratio(self) -> Tuple[int, int]:
        return len(self.members("P")), len(self.members("D"))

    # -------------------------------------- dynamic RoCE adjustment (§3.3)
    def adjust_ratio(self, t: float, n_p: int, n_d: int) -> float:
        """Dynamic RoCE construction: stateless containers join / leave;
        running instances are never interrupted."""
        cur_p, cur_d = self.ratio
        # removals: logical removal first (no new traffic), then erase
        for iid in self.members("P")[n_p:]:
            self.meta.remove_instance(t, iid)
        for iid in self.members("D")[n_d:]:
            self.meta.remove_instance(t, iid)
        added = max(0, n_p - cur_p) + max(0, n_d - cur_d)
        for _ in range(max(0, n_p - cur_p)):
            self.meta.gather_instance(t, f"{self.gid}/P{next(_iid)}", "P",
                                      self.gid)
        for _ in range(max(0, n_d - cur_d)):
            self.meta.gather_instance(t, f"{self.gid}/D{next(_iid)}", "D",
                                      self.gid)
        if added:
            # new connections + model load for the added containers only
            t += T_CONNECT + (T_LOAD_SSD if self.storage == "ssd"
                              else T_LOAD_SFS)
        t += T_HEALTH  # zookeeper pushes updated decode meta to prefills
        self.timeline.append(WorkflowEvent(t, "ratio_adjusted",
                                           f"{n_p}:{n_d}"))
        return t

    # ----------------------------------------------- rolling upgrade (§3.3)
    def rolling_upgrade(self, t: float, groups: List["PDGroup"]) -> float:
        """Upgrade one group after another; each group keeps its P/D ratio
        so the service is never interrupted (traffic shifts to peers)."""
        for g in groups:
            n_p, n_d = g.ratio
            t = g.adjust_ratio(t, n_p, n_d)  # reload with new artifacts
            g.timeline.append(WorkflowEvent(t, "upgraded"))
        return t
