"""Request model + scenario-structured synthetic workload (paper §2.2.1).

Prompts have a shared scenario prefix (the "setting part": system text,
candidate pools, background facts) and a per-request query part. Scenarios
differ in prefix length, prompt length, and output-token distributions, and
traffic is tidal (Fig. 2a / 13b).
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class Request:
    rid: int
    scenario: str
    prefix_id: str            # which cached prefix this prompt shares
    prefix_len: int           # tokens coverable by a prefix-KVCache hit
    prompt_len: int           # total prompt tokens (prefix + query)
    output_tokens: int        # tokens to generate in decode
    arrival: float            # seconds
    slo_ttft: float           # TTFT SLO threshold (s)
    # ---- lifecycle (filled by the system) ----
    t_accept: float = -1.0
    t_prefill_done: float = -1.0
    t_prefill_compute: float = 0.0   # batch compute time (overlap model)
    t_transfer_done: float = -1.0
    t_done: float = -1.0
    timed_out: bool = False
    rejections: int = 0
    prefix_hit: bool = False

    @property
    def ttft(self) -> float:
        return self.t_prefill_done - self.arrival

    @property
    def e2e(self) -> float:
        return self.t_done - self.arrival


@dataclass(frozen=True)
class Scenario:
    name: str
    service: str
    prefix_len: int            # tokens in the shared setting part
    num_prefixes: int          # distinct prefixes in this scenario
    query_len_mean: int
    query_len_std: int
    out_tokens_mean: int
    out_tokens_std: int
    slo_ttft: float = 3.0
    weight: float = 1.0        # share of traffic


# Six scenarios from two services, mirroring the paper's Fig. 1a spread:
# short-prefix chat, long candidate-pool ranking, RAG summarization, etc.
DEFAULT_SCENARIOS: Tuple[Scenario, ...] = (
    Scenario("svcA/chat", "svcA", 512, 4, 256, 96, 220, 80, 2.0, 1.5),
    Scenario("svcA/rank", "svcA", 3072, 8, 192, 64, 24, 8, 2.5, 1.2),
    Scenario("svcA/summ", "svcA", 1536, 6, 1024, 256, 330, 96, 4.0, 0.8),
    Scenario("svcB/extract", "svcB", 2048, 10, 512, 128, 48, 16, 2.5, 1.0),
    Scenario("svcB/code", "svcB", 1024, 5, 768, 256, 512, 128, 4.0, 0.7),
    Scenario("svcB/qa", "svcB", 4096, 12, 128, 48, 96, 32, 3.0, 0.8),
)


def tidal_rate(base_rps: float, t: float, *, period: float = 86400.0,
               trough: float = 0.25) -> float:
    """Day/night tidal traffic (Fig. 13b): peak at mid-period."""
    phase = 2 * math.pi * (t % period) / period
    return base_rps * (trough + (1 - trough) * 0.5 * (1 - math.cos(phase)))


class WorkloadGenerator:
    """Poisson arrivals per scenario with shared-prefix structure."""

    def __init__(self, scenarios=DEFAULT_SCENARIOS, *, base_rps: float = 8.0,
                 seed: int = 0, tidal: bool = False):
        self.scenarios = list(scenarios)
        self.base_rps = base_rps
        self.rng = random.Random(seed)
        self.tidal = tidal
        self._rid = 0
        wsum = sum(s.weight for s in self.scenarios)
        self._weights = [s.weight / wsum for s in self.scenarios]

    def _draw_scenario(self) -> Scenario:
        return self.rng.choices(self.scenarios, weights=self._weights)[0]

    def make_request(self, t: float) -> Request:
        sc = self._draw_scenario()
        self._rid += 1
        q = max(16, int(self.rng.gauss(sc.query_len_mean, sc.query_len_std)))
        out = max(1, int(self.rng.gauss(sc.out_tokens_mean, sc.out_tokens_std)))
        pid = f"{sc.name}#p{self.rng.randrange(sc.num_prefixes)}"
        return Request(
            rid=self._rid, scenario=sc.name, prefix_id=pid,
            prefix_len=sc.prefix_len, prompt_len=sc.prefix_len + q,
            output_tokens=out, arrival=t, slo_ttft=sc.slo_ttft)

    def arrivals(self, horizon: float, *, rate: Optional[float] = None
                 ) -> List[Request]:
        """All requests in [0, horizon)."""
        out: List[Request] = []
        t = 0.0
        while True:
            r = rate if rate is not None else self.base_rps
            if self.tidal:
                r = tidal_rate(r, t)
            t += self.rng.expovariate(max(r, 1e-9))
            if t >= horizon:
                return out
            out.append(self.make_request(t))
