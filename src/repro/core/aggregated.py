"""Aggregated-serving baseline (the paper's comparison point).

Each instance runs BOTH phases: prefill batches preempt decoding (shared
compute + shared HBM), KVCache stays local (no D2D transfer), and batch
sizes cannot be tuned per phase. This is the pre-disaggregation deployment
the paper reports a 6.7x E2E-throughput improvement over.
"""
from __future__ import annotations

import random
from typing import Dict, List, Optional, Sequence

from repro.core.cluster_sim import SimClock
from repro.core.profiles import ServingProfile
from repro.core.requests import Request


class AggregatedInstance:
    def __init__(self, sim: "AggregatedSim", iid: str,
                 profile: ServingProfile, *, b_p: int, b_d: int):
        self.sim = sim
        self.iid = iid
        self.profile = profile
        self.b_p = b_p
        # aggregated deployments keep a smaller decode batch: weights,
        # prefill activations and KV share one HBM
        self.b_d = b_d
        self.queue: List[Request] = []
        self.decoding: Dict[int, List] = {}
        self.prefilling = False
        self._running = False

    @property
    def load(self) -> int:
        return len(self.queue) + len(self.decoding)

    def submit(self, req: Request):
        self.queue.append(req)
        self._kick()

    def _kick(self):
        if not self._running and (self.queue or self.decoding):
            self._running = True
            self.sim.clock.schedule(0.0, self._cycle)

    def _cycle(self):
        """Alternate: one prefill batch (if pending + decode room), then
        decode iterations. Prefill BLOCKS decoding on the shared chips."""
        t = self.sim.clock.t
        if self.queue and len(self.decoding) < self.b_d:
            room = self.b_d - len(self.decoding)
            nmax = min(self.b_p, room, len(self.queue))
            batch = [self.queue.pop(0) for _ in range(nmax)]
            if batch:
                tokens = sum(r.prompt_len for r in batch)
                dt = self.profile.ttft(tokens, 0)

                def done():
                    tt = self.sim.clock.t
                    for r in batch:
                        r.t_prefill_done = tt
                        if r.ttft > r.slo_ttft:
                            r.timed_out = True
                            self.sim.failed.append(r)
                            continue
                        r.t_transfer_done = tt   # local, no D2D
                        self.decoding[r.rid] = [r, r.output_tokens]
                    self._step_decode()

                self.sim.clock.schedule(dt, done)
                return
        self._step_decode()

    def _step_decode(self):
        if not self.decoding:
            if self.queue:
                self.sim.clock.schedule(0.0, self._cycle)
            else:
                self._running = False
            return
        dt = self.profile.tpot(len(self.decoding))

        def fire():
            done_rids = []
            for rid, slot in self.decoding.items():
                slot[1] -= 1
                if slot[1] <= 0:
                    done_rids.append(rid)
            for rid in done_rids:
                req = self.decoding.pop(rid)[0]
                req.t_done = self.sim.clock.t
                self.sim.completed.append(req)
            self.sim.clock.schedule(0.0, self._cycle)

        self.sim.clock.schedule(dt, fire)


class AggregatedSim:
    def __init__(self, profile: ServingProfile, *, n_instances: int,
                 b_p: int = 4, b_d: int = 8, seed: int = 0):
        self.clock = SimClock()
        self.rng = random.Random(seed)
        self.instances = [AggregatedInstance(self, f"A{i}", profile,
                                             b_p=b_p, b_d=b_d)
                          for i in range(n_instances)]
        self.completed: List[Request] = []
        self.failed: List[Request] = []

    def submit(self, req: Request):
        tgt = min(self.instances, key=lambda x: x.load)
        tgt.submit(req)

    def run(self, requests: Sequence[Request], horizon: float
            ) -> Dict[str, float]:
        for r in requests:
            self.clock.schedule(r.arrival - self.clock.t,
                                (lambda rr: (lambda: self.submit(rr)))(r))
        self.clock.run_until(horizon)
        ok = len(self.completed)
        tot = ok + len(self.failed)
        n = len(self.instances)
        return {
            "completed": ok,
            "success_rate": ok / tot if tot else 1.0,
            "throughput_rps": ok / horizon,
            "phi": ok / horizon / n,
        }
