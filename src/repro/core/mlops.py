"""MLOps control plane (paper §3.3-3.4): health monitoring, minimum-cost
auto recovery, group-based auto scaling, and P/D ratio recommendation.

The fault path follows the paper exactly: a per-node resident monitor
writes xPU status to a (mounted) file; MLOps polls it, classifies fault
levels, logically removes the instance in the Zookeeper meta (no new
traffic), spawns ONE stateless substitute container, runs dynamic RoCE
construction + model load, and only then re-admits it — no harm to the
running service, and running requests are completed/cleaned by the
protection path (default texts, stop zombie connections).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.group import (PDGroup, T_CONNECT, T_HEALTH, T_LOAD_SFS,
                              T_LOAD_SSD)
from repro.core.perf_model import BottleneckMonitor, InstanceProfile, \
    optimal_ratio
from repro.core.requests import tidal_rate
from repro.core.zookeeper import MetaStore

FAULT_LEVELS = ("recoverable", "device_reset", "node_replace")


def substitute_ready_delay(level: str = "node_replace", *,
                           storage: str = "ssd") -> float:
    """Seconds from fault detection to a substitute taking traffic
    (Fig. 13c/d closed form). The REAL serving path's fault controller
    (serving/faults.py) charges this same timeline on its virtual clock,
    so sim recovery walls and ServeGroup recovery walls are one model:

      * recoverable   — restart in place, only the health check;
      * device_reset  — dynamic RoCE reconstruction + health check;
      * node_replace  — one stateless substitute container: connect +
                        pre-compiled model load (SSD or SFS) + health.
    """
    t_load = T_LOAD_SSD if storage == "ssd" else T_LOAD_SFS
    if level == "recoverable":
        return T_HEALTH
    if level == "device_reset":
        return T_CONNECT + T_HEALTH
    return T_CONNECT + t_load + T_HEALTH


@dataclass(frozen=True)
class SLOSpec:
    """Per-scenario latency targets the goodput model scores against."""
    ttft_s: float
    tpot_s: float


@dataclass
class GoodputModel:
    """DistServe-style SLO goodput: requests/s that meet BOTH the TTFT
    and the TPOT SLO, not raw throughput.

    Fed by the measured ``transfer_stats()`` medians of the live group
    (``prefill_batch_median_s`` / ``decode_step_median_s``), so the
    model tracks the engines as compiled, not a roofline guess. Node
    counts are *effective* counts: a node whose class scales service
    time by ``s`` contributes ``1/s`` node-equivalents, so heterogeneous
    pools fold into the same two capacity formulas.

    Prefill: a node retires ``batch_size`` requests per batch wall
    ``b``. Queueing wait grows like ``b / (1 - rho)``, so holding TTFT
    under the SLO caps utilisation at ``rho_max = 1 - b/ttft_slo`` —
    zero (infeasible) once a single batch alone overruns the budget.

    Decode: a request holds a slot for ``gen_tokens`` steps of wall
    ``d``; TPOT is infeasible when ``d`` exceeds the per-token SLO,
    else a node sustains ``slots / (gen_tokens * d)`` requests/s.
    """
    slo: SLOSpec
    prefill_batch_s: float
    decode_step_s: float
    batch_size: int = 4
    decode_slots: int = 8
    gen_tokens: float = 8.0

    @classmethod
    def from_stats(cls, slo: SLOSpec, stats: Dict[str, float], *,
                   batch_size: int = 4, decode_slots: int = 8,
                   gen_tokens: float = 8.0) -> Optional["GoodputModel"]:
        """Build from a ServeGroup ``transfer_stats()`` dict; None until
        the group has measured at least one batch and one decode step."""
        pb = float(stats.get("prefill_batch_median_s", 0.0) or 0.0)
        ds = float(stats.get("decode_step_median_s", 0.0) or 0.0)
        if pb <= 0.0 or ds <= 0.0:
            return None
        return cls(slo=slo, prefill_batch_s=pb, decode_step_s=ds,
                   batch_size=batch_size, decode_slots=decode_slots,
                   gen_tokens=max(gen_tokens, 1.0))

    # ----------------------------------------------------- capacities
    def prefill_headroom(self) -> float:
        if self.prefill_batch_s <= 0.0:
            return 1.0
        return max(0.0, 1.0 - self.prefill_batch_s / self.slo.ttft_s)

    def prefill_capacity(self, n_eff: float) -> float:
        """Requests/s ``n_eff`` prefill node-equivalents can serve while
        keeping TTFT within SLO."""
        if self.prefill_batch_s <= 0.0:
            return float("inf")
        raw = n_eff * self.batch_size / self.prefill_batch_s
        return raw * self.prefill_headroom()

    def decode_capacity(self, n_eff: float) -> float:
        """Requests/s ``n_eff`` decode node-equivalents can serve while
        keeping TPOT within SLO."""
        if self.decode_step_s <= 0.0:
            return float("inf")
        if self.decode_step_s > self.slo.tpot_s:
            return 0.0
        residency_s = self.gen_tokens * self.decode_step_s
        return n_eff * self.decode_slots / residency_s

    def goodput(self, rate: float, n_p_eff: float, n_d_eff: float) -> float:
        """Requests/s meeting both SLOs at offered ``rate``."""
        return min(rate, self.prefill_capacity(n_p_eff),
                   self.decode_capacity(n_d_eff))

    def nodes_needed(self, rate: float) -> Tuple[int, int]:
        """Smallest (n_p, n_d) balanced-node-equivalents serving ``rate``
        within both SLOs. Infeasible sides report a huge count so the
        caller can detect 'no amount of nodes fixes this SLO'."""
        import math
        big = 1 << 20
        per_p = self.prefill_capacity(1.0)
        per_d = self.decode_capacity(1.0)
        n_p = big if per_p <= 0.0 else max(1, math.ceil(rate / per_p))
        n_d = big if per_d <= 0.0 else max(1, math.ceil(rate / per_d))
        return n_p, n_d


@dataclass
class FaultRecord:
    t_detect: float
    iid: str
    level: str
    t_removed: float = -1.0
    t_substitute_ready: float = -1.0

    @property
    def recovery_time(self) -> float:
        return self.t_substitute_ready - self.t_detect


class NodeMonitor:
    """Per-node resident process writing xPU status to a health 'file'."""

    def __init__(self, seed: int = 0, fault_rate_per_hour: float = 0.004):
        self.rng = random.Random(seed)
        self.fault_rate = fault_rate_per_hour
        self.status: Dict[str, str] = {}     # iid -> "ok" | fault level

    def poll(self, t: float, iids: List[str], dt_hours: float
             ) -> Dict[str, str]:
        for iid in iids:
            if self.status.get(iid, "ok") != "ok":
                continue
            if self.rng.random() < self.fault_rate * dt_hours:
                self.status[iid] = self.rng.choice(FAULT_LEVELS)
        return dict(self.status)

    def clear(self, iid: str):
        self.status[iid] = "ok"


class MLOps:
    def __init__(self, meta: MetaStore, monitor: Optional[NodeMonitor] = None):
        self.meta = meta
        self.monitor = monitor or NodeMonitor()
        self.faults: List[FaultRecord] = []
        self.scale_events: List[Tuple[float, str, str]] = []

    # ------------------------------------------------- fault & recovery
    def check_and_recover(self, t: float, group: PDGroup,
                          dt_hours: float = 0.1) -> List[FaultRecord]:
        iids = group.members("P") + group.members("D")
        status = self.monitor.poll(t, iids, dt_hours)
        out = []
        for iid in iids:
            if status.get(iid, "ok") == "ok":
                continue
            rec = self.recover(t, group, iid, status[iid])
            out.append(rec)
        return out

    def recover(self, t: float, group: PDGroup, iid: str,
                level: str) -> FaultRecord:
        """Minimum-cost substitution: exactly ONE new stateless container."""
        rec = FaultRecord(t, iid, level)
        meta = self.meta.instances.get(iid)
        role = meta.role if meta else "P"
        # 1. logical removal: update zk meta -> no further forwarding;
        #    peers are informed so no transfer targets the fault instance
        self.meta.remove_instance(t, iid)
        rec.t_removed = t
        # 2. one substitute container: dynamic RoCE construction + load
        t_ready = t + T_CONNECT + T_LOAD_SSD + T_HEALTH
        new_iid = f"{iid.split('+')[0]}+s{len(self.faults)}"
        self.meta.gather_instance(t_ready, new_iid, role, group.gid)
        self.meta.health_report(t_ready, new_iid)
        rec.t_substitute_ready = t_ready
        self.monitor.clear(iid)
        self.faults.append(rec)
        return rec

    # -------------------------------------------------- group scaling
    def auto_scale(self, t: float, group: PDGroup, base_rps: float,
                   rps_capacity_per_pair: float, *,
                   tidal: bool = True) -> Optional[str]:
        """Time-triggered group-granularity scale in/out (Fig. 13b)."""
        rate = tidal_rate(base_rps, t) if tidal else base_rps
        n_p, n_d = group.ratio
        pairs = max(min(n_p, n_d), 1)
        have = pairs * rps_capacity_per_pair
        if rate > have * 0.9:
            group.adjust_ratio(t, n_p + 1, n_d + 1)
            self.scale_events.append((t, group.gid, "scale_out"))
            return "scale_out"
        if rate < have * 0.45 and min(n_p, n_d) > 1:
            group.adjust_ratio(t, n_p - 1, n_d - 1)
            self.scale_events.append((t, group.gid, "scale_in"))
            return "scale_in"
        return None

    # ------------------------------------------------ ratio adjustment
    def recommend_ratio(self, profile: InstanceProfile, total: int
                        ) -> Tuple[int, int]:
        return optimal_ratio(profile, total)

    def maybe_adjust_ratio(self, t: float, group: PDGroup,
                           monitor: BottleneckMonitor,
                           profile: InstanceProfile) -> Optional[str]:
        """Online path (Fig. 12c): E2E alarm + T_p proportion trend."""
        rec = monitor.recommendation()
        if rec is None:
            return None
        n_p, n_d = group.ratio
        if rec == "more_prefill":
            group.adjust_ratio(t, n_p + 1, max(n_d - 1, 1))
        else:
            group.adjust_ratio(t, max(n_p - 1, 1), n_d + 1)
        return rec
