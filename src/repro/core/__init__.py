# P/D-Serve core: the paper's contribution as a composable system.
#
#   perf_model    — E2E model (Phi, T_p, T_d) + Eq.1 ratio optimizer
#   requests      — scenario-structured workload (shared prefixes, tidal)
#   prefix_cache  — HBM-budgeted prefix-KVCache placement (C1)
#   profiles      — roofline-derived serving cost profiles
#   zookeeper     — service/scenario/group/RoCE metadata store
#   group         — fine-grained P/D groups, dynamic RoCE workflows (C1)
#   mlops         — health, minimum-cost recovery, scaling, ratio control
#   cluster_sim   — discrete-event cluster simulator (gateway policies, C2)
#   transfer      — block-free D2D KVCache transfer engine (C3)
from repro.core import (aggregated, cluster_sim, group, mlops,  # noqa: F401
                        perf_model, prefix_cache, profiles, regions,
                        requests, transfer, zookeeper)
