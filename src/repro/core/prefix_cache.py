"""Prefix-aware KVCache registry with an HBM budget (paper §2.2.1).

Each prefill instance holds prefix KVCaches in HBM next to the weights.
A mixed pool must cache every scenario's prefixes and thrashes; a
fine-grained P/D group serves one scenario and keeps its prefixes hot —
this is the mechanism behind the paper's E2E gain (Fig. 1b).

The registry is a token-level radix-ish structure simplified to
(prefix_id -> cached length), since the synthetic workload shares exact
prefixes. It is SIMULATOR-side placement accounting only (consumed by
repro.core.cluster_sim); the real serving data path has its own
block-level implementation — the refcounted radix trie inside
``repro.serving.kvcache.PagedKVPool`` (shared blocks, COW tail, LRU
eviction) feeding ``PrefillEngine.run_suffix`` suffix-only prefill.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


@dataclass
class PrefixEntry:
    prefix_id: str
    tokens: int
    nbytes: int
    hits: int = 0
    # recurrent-state snapshot bytes riding with the entry (SSM/hybrid
    # scenarios): budgeted, inserted, and evicted in lockstep with the
    # KV bytes — the placement-accounting twin of PagedKVPool._snaps
    state_nbytes: int = 0


class PrefixCache:
    """LRU prefix-KVCache placement under an HBM byte budget."""

    def __init__(self, budget_bytes: int, kv_bytes_per_token: int,
                 state_bytes_per_prefix: int = 0):
        self.budget = int(budget_bytes)
        self.kv_bpt = int(kv_bytes_per_token)
        self.state_bpp = int(state_bytes_per_prefix)
        self._entries: "OrderedDict[str, PrefixEntry]" = OrderedDict()
        self.used = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.state_bytes = 0        # resident snapshot bytes (lockstep)

    # ------------------------------------------------------------ queries
    def lookup(self, prefix_id: str, prefix_len: int) -> int:
        """Returns cached token count (0 = miss). Marks recency."""
        e = self._entries.get(prefix_id)
        if e is None or e.tokens < prefix_len:
            self.misses += 1
            return e.tokens if e else 0
        self._entries.move_to_end(prefix_id)
        e.hits += 1
        self.hits += 1
        return prefix_len

    @property
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    # ------------------------------------------------------------ updates
    def insert(self, prefix_id: str, prefix_len: int) -> bool:
        """Cache a prefix after computing it; evicts LRU entries as needed.
        Returns False if it can never fit. A snapshot payload
        (``state_bytes_per_prefix``) is budgeted with the KV bytes and
        dies with the entry — it never outlives its prefix."""
        nbytes = prefix_len * self.kv_bpt + self.state_bpp
        if nbytes > self.budget:
            return False
        old = self._entries.pop(prefix_id, None)
        if old is not None:
            self.used -= old.nbytes
            self.state_bytes -= old.state_nbytes
        while self.used + nbytes > self.budget and self._entries:
            _, ev = self._entries.popitem(last=False)
            self.used -= ev.nbytes
            self.state_bytes -= ev.state_nbytes
            self.evictions += 1
        e = PrefixEntry(prefix_id, prefix_len, nbytes,
                        hits=old.hits if old else 0,
                        state_nbytes=self.state_bpp)
        self._entries[prefix_id] = e
        self.used += nbytes
        self.state_bytes += self.state_bpp
        return True

    def drop(self, prefix_id: str):
        e = self._entries.pop(prefix_id, None)
        if e is not None:
            self.used -= e.nbytes
            self.state_bytes -= e.state_nbytes

    def __contains__(self, prefix_id: str) -> bool:
        return prefix_id in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def invariant_ok(self) -> bool:
        return (self.used == sum(e.nbytes for e in self._entries.values())
                and self.state_bytes == sum(e.state_nbytes
                                            for e in self._entries.values())
                and self.used <= self.budget)


class TieredPrefixCache:
    """HBM + host-memory prefix pool (paper §6.2, multi-turn extension).

    HBM hits are free; host hits pay a load penalty (PCIe/DMA) but beat
    recomputing the prefix; evictions from HBM spill to the host tier.
    Fine-grained P/D groups raise BOTH tiers' hit rates (scenario
    affinity), which is why the pool is per-group.
    """

    def __init__(self, hbm_budget: int, host_budget: int,
                 kv_bytes_per_token: int, *,
                 host_load_bw: float = 20e9):
        self.hbm = PrefixCache(hbm_budget, kv_bytes_per_token)
        self.host = PrefixCache(host_budget, kv_bytes_per_token)
        self.kv_bpt = kv_bytes_per_token
        self.host_load_bw = host_load_bw
        self.host_hits = 0

    def lookup(self, prefix_id: str, prefix_len: int
               ) -> "tuple[int, float]":
        """Returns (cached_tokens, load_seconds)."""
        got = self.hbm.lookup(prefix_id, prefix_len)
        if got >= prefix_len:
            return got, 0.0
        got_host = self.host.lookup(prefix_id, prefix_len)
        if got_host >= prefix_len:
            self.host_hits += 1
            load = prefix_len * self.kv_bpt / self.host_load_bw
            self._promote(prefix_id, prefix_len)
            return got_host, load
        return max(got, got_host), 0.0

    def insert(self, prefix_id: str, prefix_len: int):
        # track HBM evictions so they spill to host instead of vanishing
        before = {pid: e.tokens for pid, e in self.hbm._entries.items()}
        self.hbm.insert(prefix_id, prefix_len)
        for pid, tokens in before.items():
            if pid not in self.hbm and pid != prefix_id:
                self.host.insert(pid, tokens)

    def _promote(self, prefix_id: str, prefix_len: int):
        self.insert(prefix_id, prefix_len)

    def invariant_ok(self) -> bool:
        return self.hbm.invariant_ok() and self.host.invariant_ok()
