"""Minimal AdamW in pure JAX (pytree-generic), fp32 moments."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig = AdamWConfig()
                 ) -> Tuple[Any, Dict[str, Any], jax.Array]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        u = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * u).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, gnorm
