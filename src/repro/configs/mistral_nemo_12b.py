"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407].

40L d_model=5120 32H (kv=8, head_dim=128) d_ff=14336 vocab=131072, 128k ctx.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    rope_theta=1_000_000.0,
    max_seq_len=131072,
)
