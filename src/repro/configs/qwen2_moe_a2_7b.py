"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16) routed-expert d_ff=1408, vocab=151936,
MoE 60 routed top-4 + 4 shared experts.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    arch_type="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=60, top_k=4, num_shared_experts=4,
                  d_ff_expert=1408, layout="all"),
)
