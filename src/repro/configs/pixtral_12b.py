"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409].

Language decoder (mistral-nemo backbone): 40L d_model=5120 32H (kv=8)
d_ff=14336 vocab=131072. The Pixtral-ViT vision frontend is a STUB —
input_specs provide precomputed patch embeddings of shape (b, s, d_model).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    arch_type="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    rope_theta=1_000_000_000.0,
    frontend="vision",
)
