"""Jamba-1.5-Large (398B) [arXiv:2403.19887].

72L d_model=8192, attention:mamba = 1:7 interleave (1 attn layer per 8),
attn 64H (GQA kv=8), MoE 16 experts top-2 (every other layer) d_ff=24576,
vocab=65536, Mamba(2) ssm_state=128.
"""
from repro.models.config import ModelConfig, MoEConfig, SSMConfig, ATTN, MAMBA

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    rope_theta=10_000.0,
    layer_block=(MAMBA, MAMBA, MAMBA, MAMBA, ATTN, MAMBA, MAMBA, MAMBA),
    moe=MoEConfig(num_experts=16, top_k=2, num_shared_experts=0,
                  d_ff_expert=24576, layout="every_other"),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2),
    max_seq_len=262144,
)
