"""Pangu-like dense model standing in for the paper's own workloads [Pangu, arXiv:2303.10845].

The paper serves Pangu variants (sizes vary per scenario); we model a
38B-class dense GQA decoder as the paper-faithful serving target.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pangu-38b",
    arch_type="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=100352,
    head_dim=128,
    rope_theta=1_000_000.0,
)
