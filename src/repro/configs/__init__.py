"""Architecture registry: one module per assigned architecture.

Each module exposes CONFIG (full-size, dry-run only) — reduced smoke
variants come from ``CONFIG.reduced()``.
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.models.config import ModelConfig

ARCH_IDS = [
    "qwen2_moe_a2_7b",
    "qwen1_5_110b",
    "pixtral_12b",
    "whisper_base",
    "deepseek_moe_16b",
    "mistral_nemo_12b",
    "jamba_1_5_large",
    "mamba2_2_7b",
    "granite_3_8b",
    "minicpm_2b",
    "pangu_38b",  # paper's own model family (Pangu-like dense)
]

# public --arch ids (dashed) -> module names
ALIASES = {
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen1.5-110b": "qwen1_5_110b",
    "pixtral-12b": "pixtral_12b",
    "whisper-base": "whisper_base",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "mamba2-2.7b": "mamba2_2_7b",
    "granite-3-8b": "granite_3_8b",
    "minicpm-2b": "minicpm_2b",
    "pangu-38b": "pangu_38b",
}

ASSIGNED = [a for a in ALIASES if a != "pangu-38b"]


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ALIASES}
