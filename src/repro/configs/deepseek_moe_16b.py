"""DeepSeekMoE-16B [arXiv:2401.06066].

28L d_model=2048 16H d_ff(expert)=1408 vocab=102400,
fine-grained MoE: 2 shared + 64 routed top-6.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    arch_type="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    head_dim=128,
    rope_theta=10_000.0,
    moe=MoEConfig(num_experts=64, top_k=6, num_shared_experts=2,
                  d_ff_expert=1408, layout="all"),
)
