"""Mamba2-2.7B [arXiv:2405.21060] — SSD (state-space duality).

64L d_model=2560, attention-free, d_ff=0 (no MLP; Mamba2 block only),
vocab=50280, ssm_state=128, headdim=64 -> 80 SSD heads.
"""
from repro.models.config import ModelConfig, SSMConfig, MAMBA

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    head_dim=64,
    tie_embeddings=True,
    layer_block=(MAMBA,),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2),
    max_seq_len=1048576,
)
