"""MiniCPM-2B [arXiv:2404.06395] — llama-like, WSD schedule.

40L d_model=2304 36H (kv=36) d_ff=5760 vocab=122753.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    arch_type="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    head_dim=64,
    tie_embeddings=True,
    rope_theta=10_000.0,
)
