"""Whisper-base [arXiv:2212.04356].

Encoder-decoder, 6L enc + 6L dec, d_model=512 8H d_ff=2048 vocab=51865.
Mel-spectrogram + conv frontend is a STUB — input_specs provide
precomputed frame embeddings (b, 1500, 512).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    arch_type="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    head_dim=64,
    encoder_layers=6,
    encoder_seq=1500,
    frontend="audio",
    rope_theta=10_000.0,
)
