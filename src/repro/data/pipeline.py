"""Deterministic synthetic LM data pipeline.

Produces next-token-prediction batches with a learnable structure (piecewise
Markov chains per "scenario", sharing prefixes) so the training example's
loss actually decreases. Shardable: batch index -> content is a pure
function of (seed, step), so every data-parallel worker can slice its rows
without coordination.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


def text_to_tokens(text: str, vocab_size: int) -> np.ndarray:
    """Toy byte-pair-ish tokenizer stub: bytes folded into the vocab."""
    raw = np.frombuffer(text.encode("utf-8"), np.uint8).astype(np.int64)
    return (raw * 31 + 7) % max(vocab_size, 2)


@dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    order: int = 2          # markov order of the synthetic language

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # sparse transition table: each previous token admits 4 successors
        n_ctx = min(self.vocab_size, 4096)
        self._n_ctx = n_ctx
        self._cands = rng.integers(0, self.vocab_size,
                                   size=(n_ctx, 4)).astype(np.int64)

    def _ctx_hash(self, a: np.ndarray) -> np.ndarray:
        return a % self._n_ctx

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        b, s = self.global_batch, self.seq_len
        toks = np.zeros((b, s + 1), np.int64)
        toks[:, 0] = rng.integers(0, self.vocab_size, b)
        noise = rng.random((b, s + 1))
        pick = rng.integers(0, 4, (b, s + 1))
        for t in range(1, s + 1):
            h = self._ctx_hash(toks[:, t - 1])
            nxt = self._cands[h, pick[:, t]]
            rand = rng.integers(0, self.vocab_size, b)
            toks[:, t] = np.where(noise[:, t] < 0.05, rand, nxt)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
