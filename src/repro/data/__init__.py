from repro.data.pipeline import SyntheticLM, text_to_tokens  # noqa: F401
