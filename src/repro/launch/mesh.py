"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state. The dry-run process sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the single real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = 1
    for s in shape:
        ndev *= s
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"mesh {shape} needs {ndev} devices but only {len(devices)} are "
            f"visible; the dry-run entrypoint must set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            f"importing jax")
    import numpy as np
    dev_array = np.asarray(devices[:ndev]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    """Single-device mesh for CPU tests."""
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(shape), axes)


# TPU v5e hardware constants for the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (approx, per direction)
HBM_PER_CHIP = 16 * 1024**3   # 16 GiB
