import os
# LICM disabled: XLA-CPU otherwise hoists whole-residual-stack converts out
# of the backward while loop (+10GiB/device on the 110B train lowering).
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion "
    + os.environ.get("XLA_FLAGS", ""))

# --- everything below may import jax ---------------------------------------
import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ALIASES, get_config  # noqa: E402
from repro.launch import roofline as roofline_lib  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import input_specs  # noqa: E402
from repro.models.config import INPUT_SHAPES  # noqa: E402
from repro.models.steps import (  # noqa: E402
    decode_window, make_prefill_step, make_serve_step, make_train_step)


def model_flops_total(cfg, shape) -> float:
    """6·N·D (train) / 2·N·D (prefill) / 2·N·B (decode), N = active params
    excluding the embedding lookup (tied embeddings count once as the head)."""
    n = cfg.param_count(active_only=True)
    if not cfg.tie_embeddings:
        n -= cfg.vocab_size * cfg.d_model  # lookup-only embedding
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def skip_reason(arch: str, shape_name: str) -> str:
    if arch == "whisper-base" and shape_name == "long_500k":
        return ("skip: encoder-decoder with hard 448-token decoder limit; "
                "512k windowed decoder is out-of-family (DESIGN.md)")
    return ""


def auto_microbatches(cfg, shape, multi_pod: bool) -> int:
    """Gradient-accumulation factor for the train shape: big residual
    streams need activation transients divided to fit 16GiB v5e HBM."""
    if shape.kind != "train":
        return 1
    if cfg.d_model >= 8192:
        return 16 if not multi_pod else 8
    if cfg.d_model >= 4096:
        return 4 if not multi_pod else 2
    if cfg.d_model >= 2048:
        return 2
    return 1


def lower_one(arch: str, shape_name: str, *, multi_pod: bool, mode: str = "",
              microbatches: int = 0, moe_dispatch: str = ""):
    cfg = get_config(arch)
    if moe_dispatch and cfg.moe is not None:
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  dispatch=moe_dispatch))
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    mode = mode or shape.kind
    args, shardings = input_specs(cfg, shape, mesh, mode=mode)

    if shape.kind == "train":
        mb = microbatches or auto_microbatches(cfg, shape, multi_pod)
        step = make_train_step(cfg, mesh=mesh, microbatches=mb)
        donate = (0, 1)
    elif shape.kind == "prefill":
        act_rules = None
        if mode.endswith("_ep"):
            from repro.distribution.ctx import ACT_RULES_EP
            act_rules = ACT_RULES_EP
        step = make_prefill_step(cfg, mesh=mesh, act_rules=act_rules)
        donate = ()
    else:
        step = make_serve_step(cfg, window=decode_window(cfg, shape), mesh=mesh)
        donate = (1,)

    with mesh:
        jitted = jax.jit(step, in_shardings=shardings,
                         donate_argnums=donate)
        t0 = time.time()
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mf = model_flops_total(cfg, shape)
    from repro.models import caches as caches_lib
    from repro.models.params import param_count_actual
    p_dev = param_count_actual(cfg) * 2.0 / chips
    if shape.kind == "decode":
        w = decode_window(cfg, shape)
        cache_dev = caches_lib.cache_num_bytes(
            cfg, shape.global_batch, shape.seq_len, window=w) / chips
        tokens_dev = shape.global_batch / chips
    else:
        cache_dev = (caches_lib.cache_num_bytes(
            cfg, shape.global_batch, shape.seq_len) / chips
            if shape.kind == "prefill" else 0.0)
        tokens_dev = shape.global_batch * shape.seq_len / chips
    floor = roofline_lib.analytic_bytes_floor(
        params_bytes_dev=p_dev, cache_bytes_dev=cache_dev,
        tokens_dev=tokens_dev, d_model=cfg.d_model,
        num_layers=cfg.num_layers, kind=shape.kind)
    rl = roofline_lib.analyze(compiled, chips=chips, model_flops_total=mf,
                              bytes_floor=floor)
    return compiled, rl, {"t_lower": t_lower, "t_compile": t_compile,
                          "chips": chips, "mode": mode}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", required=True, choices=sorted(ALIASES))
    ap.add_argument("--shape", required=True, choices=sorted(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="", help="override sharding mode "
                    "(e.g. decode_opt)")
    ap.add_argument("--moe-dispatch", default="",
                    choices=["", "capacity", "sorted"])
    ap.add_argument("--out", default="", help="write JSON result here")
    ap.add_argument("--quiet", action="store_true")
    a = ap.parse_args(argv)

    reason = skip_reason(a.arch, a.shape)
    result = {"arch": a.arch, "shape": a.shape,
              "mesh": "2x16x16" if a.multi_pod else "16x16"}
    if reason:
        result["skipped"] = reason
        print(reason)
    else:
        compiled, rl, meta = lower_one(a.arch, a.shape,
                                       multi_pod=a.multi_pod, mode=a.mode,
                                       moe_dispatch=a.moe_dispatch)
        if not a.quiet:
            print(compiled.memory_analysis())   # proves it fits
            ca = compiled.cost_analysis()
            print({k: ca[k] for k in ("flops", "bytes accessed")
                   if k in ca})                  # FLOPs/bytes for §Roofline
        result.update(meta)
        result["roofline"] = rl.to_dict()
        print(f"[dryrun] {a.arch} x {a.shape} x {result['mesh']} "
              f"mode={meta['mode']} OK  "
              f"compute={rl.compute_s*1e3:.2f}ms memory={rl.memory_s*1e3:.2f}ms "
              f"collective={rl.collective_s*1e3:.2f}ms dominant={rl.dominant} "
              f"mem/dev={rl.mem_per_dev_bytes/2**30:.2f}GiB fits={rl.fits_hbm} "
              f"(lower {meta['t_lower']:.1f}s compile {meta['t_compile']:.1f}s)")

    if a.out:
        with open(a.out, "w") as f:
            json.dump(result, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
