"""ShapeDtypeStruct stand-ins for every model input, plus their shardings.

`input_specs(cfg, shape)` builds the abstract arguments for the step the
shape exercises (train / prefill / decode) — weak-type-correct, shardable,
no device allocation. Used by the dry-run and AOT launchers.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distribution.sharding import (
    ShardingRules, batch_axes_for, make_shardings)
from repro.models import caches as caches_lib
from repro.models import params as params_lib
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.steps import decode_window

Tree = Any


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Tree:
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    batch: Tree = {}
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "vision":
            batch["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                   jnp.bfloat16)
        else:
            batch["tokens"] = tok
        if cfg.is_encoder_decoder:
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        if shape.kind == "train":
            batch["labels"] = tok
    return batch


def abstract_opt_state(params_abs: Tree) -> Tree:
    f32 = lambda sds: jax.ShapeDtypeStruct(sds.shape, jnp.float32)
    return {
        "mu": jax.tree.map(f32, params_abs),
        "nu": jax.tree.map(f32, params_abs),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                mode: Optional[str] = None,
                param_dtype=jnp.bfloat16
                ) -> Tuple[Tuple[Tree, ...], Tuple[Tree, ...]]:
    """Returns (abstract_args, in_shardings) for the step of `shape`."""
    mode = mode or shape.kind
    rules = ShardingRules.for_mode(mode)
    p_abs = params_lib.abstract_params(cfg, dtype=param_dtype)
    p_axes = params_lib.param_axes(cfg)
    p_shard = make_shardings(p_axes, p_abs, mesh, rules.params)

    if shape.kind == "train":
        batch = batch_specs(cfg, shape)
        b_shard = make_shardings(batch_axes_for(batch), batch, mesh,
                                 rules.batch)
        opt = abstract_opt_state(p_abs)
        opt_shard = {
            "mu": jax.tree.map(lambda _, s: s, opt["mu"], p_shard),
            "nu": jax.tree.map(lambda _, s: s, opt["nu"], p_shard),
            "step": jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()),
        }
        return (p_abs, opt, batch), (p_shard, opt_shard, b_shard)

    if shape.kind == "prefill":
        batch = batch_specs(cfg, shape)
        b_shard = make_shardings(batch_axes_for(batch), batch, mesh,
                                 rules.batch)
        return (p_abs, batch), (p_shard, b_shard)

    if shape.kind == "decode":
        window = decode_window(cfg, shape)
        cache = caches_lib.abstract_cache(cfg, shape.global_batch,
                                          shape.seq_len, window=window)
        c_axes = caches_lib.cache_axes(cfg, shape.global_batch,
                                       shape.seq_len, window=window)
        c_shard = make_shardings(c_axes, cache, mesh, rules.cache)
        tok = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        t_shard = make_shardings((("batch",),), (tok,), mesh, rules.batch)[0]
        return (p_abs, cache, tok), (p_shard, c_shard, t_shard)

    raise ValueError(shape.kind)
