"""Roofline-term extraction from a compiled (dry-run) artifact.

compute term    = HLO_FLOPs_per_device / peak_FLOP/s
memory term     = HLO_bytes_per_device / HBM_bw
collective term = collective_bytes_per_device / link_bw

FLOPs/bytes come from the loop-aware HLO cost model in
``repro.launch.hlo_cost`` — ``Compiled.cost_analysis()`` counts while-loop
bodies once (verified empirically), which under-counts every scanned model
by ~num_layers x; its raw values are still recorded for reference.
collective_bytes are likewise NOT in cost_analysis: the cost model sums the
output-buffer sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute with loop multipliers applied.

All inputs are per-device (the compiled module is the per-device SPMD
program), so terms are per-device seconds.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Dict, Optional

from repro.launch import hlo_cost, mesh as mesh_lib


def analytic_bytes_floor(*, params_bytes_dev: float, cache_bytes_dev: float,
                         tokens_dev: float, d_model: int, num_layers: int,
                         kind: str) -> float:
    """Lower bound on per-device HBM traffic for one step, independent of
    backend lowering noise. decode: weights + cache read once; prefill:
    weights once + activations ~8 tensor-touches/layer + cache write;
    train: weights fwd+bwd+remat reads, grad write/read, fp32 opt state
    read+write, activations ~12 touches/layer."""
    act = tokens_dev * d_model * 2.0 * num_layers
    if kind == "decode":
        return params_bytes_dev + cache_bytes_dev + 8 * tokens_dev * d_model * 2
    if kind == "prefill":
        return params_bytes_dev + cache_bytes_dev + 8 * act
    # train: 3 weight passes (fwd, remat-fwd, bwd) + bf16 grads (w+r) +
    # fp32 moments r+w (=8x bf16 param bytes) + fp32 master update
    return 3 * params_bytes_dev + 2 * params_bytes_dev \
        + 8 * params_bytes_dev + 12 * act


@dataclass
class Roofline:
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    coll_breakdown: Dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_total: float
    useful_ratio: float       # MODEL_FLOPS / (HLO_FLOPs * chips)
    chips: int
    mem_per_dev_bytes: int
    fits_hbm: bool
    xla_flops_raw: float      # cost_analysis values, loop-undercounted
    xla_bytes_raw: float
    bytes_floor: float = 0.0  # analytic lower bound actually applied

    def to_dict(self):
        return asdict(self)


def analyze(compiled, *, chips: int, model_flops_total: float,
            hlo_text: Optional[str] = None,
            bytes_floor: float = 0.0) -> Roofline:
    text = hlo_text if hlo_text is not None else compiled.as_text()
    cost = hlo_cost.analyze_text(text)
    ca = compiled.cost_analysis()
    compute_s = cost.flops / mesh_lib.PEAK_FLOPS_BF16
    memory_s = max(cost.bytes, bytes_floor) / mesh_lib.HBM_BW
    collective_s = cost.coll_bytes / mesh_lib.ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    ma = compiled.memory_analysis()
    mem = int(ma.argument_size_in_bytes + ma.output_size_in_bytes
              + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    useful = model_flops_total / max(cost.flops * chips, 1.0)
    return Roofline(
        flops_per_dev=cost.flops, bytes_per_dev=cost.bytes,
        coll_bytes_per_dev=cost.coll_bytes, coll_breakdown=dict(cost.coll),
        compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, dominant=dominant,
        model_flops_total=model_flops_total, useful_ratio=useful,
        chips=chips, mem_per_dev_bytes=mem,
        fits_hbm=mem <= mesh_lib.HBM_PER_CHIP,
        xla_flops_raw=float(ca.get("flops", 0.0)),
        xla_bytes_raw=float(ca.get("bytes accessed", 0.0)),
        bytes_floor=bytes_floor)
