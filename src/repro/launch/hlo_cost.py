"""Loop-aware HLO cost model.

``Compiled.cost_analysis()`` counts while-loop bodies ONCE (verified: a
10-step scanned matmul reports 10x fewer FLOPs than its unrolled twin), so
for scan-structured models — which is everything in this repo — its numbers
are useless as roofline inputs. This module parses the post-partitioning
HLO text and computes:

  * flops        — exact for dot ops (2 * |out| * contraction), |out| for
                   elementwise approximations,
  * bytes        — sum of operand+output array bytes per (fused) op, the
                   same convention cost_analysis uses,
  * collective bytes per kind (output-buffer sizes),

with while-loop bodies multiplied by their ``known_trip_count`` backend
config (fallback: the compare-constant in the loop condition).

All quantities are PER DEVICE (the module is the per-device SPMD program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*"
    r"([\w\-]+)\((.*)$")
_TRIP_RE = re.compile(r'known_trip_count.*?"n":"(\d+)"')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _array_dims(tstr: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _ARRAY_RE.finditer(tstr):
        if m.group(1) in _DTYPE_BYTES:
            dims = [int(d) for d in m.group(2).split(",") if d]
            out.append((m.group(1), dims))
    return out


def _type_bytes(tstr: str) -> int:
    total = 0
    for dt, dims in _array_dims(tstr):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(tstr: str) -> int:
    total = 0
    for _, dims in _array_dims(tstr):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult

    @property
    def coll_bytes(self) -> float:
        return float(sum(self.coll.values()))


@dataclass
class _Instr:
    name: str
    type_str: str
    opcode: str
    rest: str  # operand list + attrs (raw tail of the line)


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[_Instr]] = {}
        self.types: Dict[str, str] = {}
        self.roots: Dict[str, _Instr] = {}
        self._parse(hlo_text)
        self._memo: Dict[str, Cost] = {}

    # ------------------------------------------------------------- parsing
    def _parse(self, text: str):
        cur: Optional[str] = None
        for line in text.splitlines():
            s = re.sub(r"/\*.*?\*/", "", line).rstrip()
            if not s:
                continue
            if s.startswith("ENTRY"):
                m = re.match(r"ENTRY\s+%?([\w\.\-]+)", s)
                cur = m.group(1)
                self.computations[cur] = []
                self.entry = cur
                continue
            if s.startswith("%") and s.endswith("{"):
                m = re.match(r"%([\w\.\-]+)\s*\(", s)
                if m:
                    cur = m.group(1)
                    self.computations[cur] = []
                continue
            if s.startswith("}"):
                cur = None
                continue
            if cur is None:
                continue
            m = _INSTR_RE.match(s)
            if not m:
                continue
            name, tstr, opcode, rest = m.groups()
            inst = _Instr(name, tstr, opcode, rest)
            self.computations[cur].append(inst)
            self.types[name] = tstr
            if s.lstrip().startswith("ROOT"):
                self.roots[cur] = inst

    # ------------------------------------------------------------ helpers
    def _operands(self, rest: str) -> List[str]:
        # Operand list terminates at the first ')' at depth 0. Newer XLA
        # dumps print operand types inline ("dot(f32[64,32]{1,0} %Arg_0.1,
        # ...)"), so splitting must also be brace-aware (layout tuples like
        # {1,0} contain commas) and the operand name is the LAST %token in
        # each comma-separated slot, not the slot's first character.
        depth = 0
        out = []
        tok = ""
        for ch in rest:
            if ch in "({":
                depth += 1
                tok += ch
            elif ch in ")}":
                if ch == ")" and depth == 0:
                    break
                depth -= 1
                tok += ch
            elif ch == "," and depth == 0:
                out.append(tok)
                tok = ""
            else:
                tok += ch
        if tok.strip():
            out.append(tok)
        names = []
        for t in out:
            m = re.findall(r"%([\w\.\-]+)", t)
            if m:
                names.append(m[-1])
        return names

    def _operand_bytes(self, rest: str) -> int:
        return sum(_type_bytes(self.types.get(o, ""))
                   for o in self._operands(rest))

    def _fusion_operand_bytes(self, rest: str, called: str) -> int:
        """Operand traffic of a fusion: an operand whose only in-fusion
        uses are dynamic-slice/gather reads only the slices it produces,
        not the whole buffer (KV-cache reads inside the decode loop)."""
        ops = self._operands(rest)
        comp = self.computations.get(called, [])
        # parameter number -> instruction name
        params: Dict[int, str] = {}
        for ci in comp:
            if ci.opcode == "parameter":
                m = re.match(r"\s*(\d+)", ci.rest)
                if m:
                    params[int(m.group(1))] = ci.name
        total = 0
        for idx, o in enumerate(ops):
            full = _type_bytes(self.types.get(o, ""))
            pname = params.get(idx)
            if pname is None:
                total += full
                continue
            uses = [ci for ci in comp
                    if ci.opcode != "parameter"
                    and pname in self._operands(ci.rest)]
            if uses and all(u.opcode in ("dynamic-slice", "gather")
                            for u in uses):
                total += sum(_type_bytes(u.type_str) for u in uses)
            else:
                total += full
        return total

    def _dot_flops(self, inst: _Instr) -> float:
        out_elems = _type_elems(inst.type_str)
        m = _LHS_C_RE.search(inst.rest)
        contract = 1
        if m:
            ops = self._operands(inst.rest)
            if ops:
                lhs = _array_dims(self.types.get(ops[0], ""))
                if lhs:
                    _, dims = lhs[0]
                    for i in (int(x) for x in m.group(1).split(",") if x):
                        if i < len(dims):
                            contract *= dims[i]
        return 2.0 * out_elems * contract

    def _trip_count(self, inst: _Instr) -> int:
        m = _TRIP_RE.search(inst.rest)
        if m:
            return int(m.group(1))
        # fallback: constant in the condition computation
        c = _COND_RE.search(inst.rest)
        if c and c.group(1) in self.computations:
            for ci in self.computations[c.group(1)]:
                if ci.opcode == "constant":
                    mm = re.search(r"constant\((\d+)\)", "constant(" + ci.rest)
                    if mm:
                        return int(mm.group(1))
        return 1

    # ---------------------------------------------------------------- cost
    _SKIP = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "add-dependency", "partition-id",
             "replica-id", "iota"}

    def cost_of(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        total = Cost()
        self._memo[comp_name] = total  # cycle guard
        for inst in self.computations.get(comp_name, []):
            op = inst.opcode
            if op in self._SKIP:
                continue
            if op == "while":
                trip = self._trip_count(inst)
                b = _BODY_RE.search(inst.rest)
                c = _COND_RE.search(inst.rest)
                if b:
                    total.add(self.cost_of(b.group(1)), trip)
                if c:
                    total.add(self.cost_of(c.group(1)), trip)
                continue
            if op in ("call", "async-start"):
                m = _CALLS_RE.search(inst.rest)
                if m and m.group(1) in self.computations:
                    total.add(self.cost_of(m.group(1)))
                continue
            if op == "conditional":
                # sum both branches (upper bound; rare in our graphs)
                for m in re.finditer(r"(?:true_computation|false_computation|"
                                     r"branch_computations=\{?)%?([\w\.\-]+)",
                                     inst.rest):
                    if m.group(1) in self.computations:
                        total.add(self.cost_of(m.group(1)))
                continue
            if op == "fusion":
                m = _CALLS_RE.search(inst.rest)
                called = m.group(1) if m else None
                if called in self.computations:
                    inner = self.cost_of(called)
                    total.flops += inner.flops
                    for k, v in inner.coll.items():
                        total.coll[k] = total.coll.get(k, 0.0) + v
                out_bytes = _type_bytes(inst.type_str)
                op_bytes = (self._fusion_operand_bytes(inst.rest, called)
                            if called in self.computations
                            else self._operand_bytes(inst.rest))
                root = self.roots.get(called) if called else None
                if root is not None and root.opcode == "dynamic-update-slice":
                    # in-place cache update: the big buffer operand aliases
                    # the output; traffic is just the small update slice(s)
                    total.bytes += 2 * max(op_bytes - out_bytes, 0)
                elif root is not None and root.opcode == "convert":
                    # CPU-lowering artifact: XLA-CPU has no native bf16 dot,
                    # so it maintains whole-buffer f32 copies of bf16 caches
                    # (observed: 2.7GB cache converted per decode layer).
                    # TPU's MXU reads bf16 directly — count nothing; the
                    # consuming dot still counts its operand reads.
                    pass
                else:
                    total.bytes += op_bytes + out_bytes
                continue
            base = op[:-6] if op.endswith("-start") else op
            if op.endswith("-done"):
                continue
            if base in COLLECTIVES:
                nbytes = _type_bytes(inst.type_str)
                total.coll[base] = total.coll.get(base, 0.0) + nbytes
                total.bytes += nbytes + self._operand_bytes(inst.rest)
                continue
            out_bytes = _type_bytes(inst.type_str)
            if op == "dynamic-update-slice":
                # in-place slice write: traffic = update operand, not the
                # whole buffer (XLA aliases operand 0 with the output)
                ops = self._operands(inst.rest)
                upd = (_type_bytes(self.types.get(ops[1], ""))
                       if len(ops) > 1 else 0)
                total.bytes += 2 * upd
                continue
            if op in ("dynamic-slice", "gather"):
                # reads only the slice it produces
                total.bytes += 2 * out_bytes
                continue
            if op == "scatter":
                ops = self._operands(inst.rest)
                upd = (_type_bytes(self.types.get(ops[-1], ""))
                       if ops else 0)
                total.bytes += 2 * upd
                continue
            total.bytes += out_bytes + self._operand_bytes(inst.rest)
            if op in ("dot", "dot_general"):
                total.flops += self._dot_flops(inst)
            elif op == "convolution":
                total.flops += 2.0 * _type_elems(inst.type_str) * 128
            elif op not in ("copy", "copy-start", "copy-done", "convert",
                            "broadcast", "reshape", "transpose", "slice",
                            "dynamic-slice", "dynamic-update-slice",
                            "concatenate", "pad", "reverse", "gather",
                            "scatter", "select", "compare"):
                total.flops += _type_elems(inst.type_str)
        self._memo[comp_name] = total
        return total

    def entry_cost(self) -> Cost:
        return self.cost_of(self.entry)


def analyze_text(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).entry_cost()
