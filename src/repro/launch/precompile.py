"""Pre-compiled model store (paper §3.2: "Pre-compiled Model Loaded in
Minutes").

Models are compiled ONCE as a subsequent task after training and written
to shared storage (SFS/SSD in the paper); every P/D instance then loads
the serialized executable instead of recompiling. Here: jax AOT
``serialize_executable`` blobs + a JSON manifest keyed by
(arch, step kind, shape) so prefill and decode instances fetch
role-specific artifacts.
"""
from __future__ import annotations

import json
import os
import pickle
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import serialize_executable as se

from repro.models.config import ModelConfig, ShapeConfig
from repro.models.steps import (decode_window, make_prefill_step,
                                make_serve_step, make_train_step)

Tree = Any


def _step_for(cfg: ModelConfig, shape: ShapeConfig, mesh=None):
    if shape.kind == "train":
        return make_train_step(cfg, mesh=mesh), (0, 1)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, mesh=mesh), ()
    return make_serve_step(cfg, window=decode_window(cfg, shape),
                           mesh=mesh), (1,)


class ArtifactStore:
    """File-backed store of serialized executables."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _paths(self, key: str) -> Tuple[str, str]:
        base = os.path.join(self.root, key.replace("/", "_"))
        return base + ".xbin", base + ".manifest.json"

    # ------------------------------------------------------------ compile
    def precompile(self, key: str, cfg: ModelConfig, shape: ShapeConfig,
                   abstract_args: Tuple, *, in_shardings=None,
                   mesh=None) -> Dict[str, float]:
        step, donate = _step_for(cfg, shape, mesh)
        t0 = time.time()
        jitted = (jax.jit(step, in_shardings=in_shardings,
                          donate_argnums=donate)
                  if in_shardings is not None
                  else jax.jit(step, donate_argnums=donate))
        compiled = jitted.lower(*abstract_args).compile()
        t_compile = time.time() - t0
        blob, in_tree, out_tree = se.serialize(compiled)
        xbin, man = self._paths(key)
        with open(xbin, "wb") as f:
            pickle.dump({"blob": blob, "in_tree": in_tree,
                         "out_tree": out_tree}, f)
        manifest = {
            "key": key, "arch": cfg.name, "kind": shape.kind,
            "seq_len": shape.seq_len, "global_batch": shape.global_batch,
            "compile_s": t_compile,
            "size_bytes": os.path.getsize(xbin),
        }
        with open(man, "w") as f:
            json.dump(manifest, f, indent=1)
        return manifest

    # --------------------------------------------------------------- load
    def load(self, key: str):
        """Instance-side load: deserialize, no recompilation."""
        xbin, man = self._paths(key)
        t0 = time.time()
        with open(xbin, "rb") as f:
            d = pickle.load(f)
        fn = se.deserialize_and_load(d["blob"], d["in_tree"], d["out_tree"])
        t_load = time.time() - t0
        manifest = json.load(open(man))
        manifest["load_s"] = t_load
        return fn, manifest

    def available(self):
        return sorted(f[:-5] for f in os.listdir(self.root)
                      if f.endswith(".xbin"))
