"""Serving launcher: run the real-compute mini-cluster on a reduced config
with a batched synthetic workload (the paper's kind of end-to-end driver).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
      --requests 16 --prefills 2 --decodes 2
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.configs import ALIASES, get_config
from repro.core.transfer import LinkModel
from repro.serving.cluster import MiniCluster, ServeRequest


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=sorted(ALIASES))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prefills", type=int, default=2)
    ap.add_argument("--decodes", type=int, default=2)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--transfer", default="block_free",
                    choices=["block_free", "block_fixed"])
    ap.add_argument("--no-overlap", action="store_true",
                    help="blocking in-tick transfer instead of the "
                         "overlapped layer-wise pipeline")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args(argv)

    cfg = get_config(a.arch).reduced()
    print(f"[serve] {cfg.name}: {a.prefills}P/{a.decodes}D "
          f"transfer={a.transfer}")
    mc = MiniCluster(cfg, n_prefill=a.prefills, n_decode=a.decodes,
                     seed=a.seed, transfer_mode=a.transfer,
                     overlap_transfer=not a.no_overlap)
    rng = np.random.default_rng(a.seed)
    reqs = []
    for i in range(a.requests):
        n = int(rng.integers(6, 20))
        frames = None
        if cfg.is_encoder_decoder:   # stub audio frontend embeddings
            frames = rng.normal(size=(cfg.encoder_seq, cfg.d_model)) * 0.1
        reqs.append(ServeRequest(
            rid=i, tokens=list(rng.integers(0, cfg.vocab_size, n)),
            max_new_tokens=a.max_new_tokens, frames=frames))
    t0 = time.time()
    done = mc.run(reqs, max_ticks=500)
    dt = time.time() - t0
    ok = sum(r.done for r in done)
    tf = mc.frontend.groups["default"].transfer_stats()
    n_tf = int(tf["jobs_admitted"])
    path = "overlapped pipeline" if tf["overlapped"] else "blocking"
    print(f"[serve] {ok}/{len(done)} completed in {dt:.1f}s wall; "
          f"gateway rejections={mc.rejections}; "
          f"transfers={n_tf} ({path}) mean_admission_wait="
          f"{tf['admission_wait_mean_s']*1e3:.2f}ms")
    for r in done[:4]:
        print(f"  rid={r.rid} prompt[{len(r.tokens)}] -> {r.generated}")
    return 0 if ok == len(done) else 1


if __name__ == "__main__":
    sys.exit(main())
