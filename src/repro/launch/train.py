"""Training launcher: real steps on CPU (reduced configs / ~100M models) or
AOT lowering against the production mesh (--dry-run goes via dryrun.py).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b \
      --reduced --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_params
from repro.configs import ALIASES, get_config
from repro.data import SyntheticLM
from repro.models.params import init_params, param_count_actual
from repro.models.steps import make_train_step
from repro.training.optimizer import AdamWConfig, adamw_init


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b", choices=sorted(ALIASES))
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced same-family variant (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (e.g. ~100M model)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--save", default="")
    ap.add_argument("--log-every", type=int, default=5)
    a = ap.parse_args(argv)

    cfg = get_config(a.arch)
    if a.reduced:
        cfg = cfg.reduced()
    if a.d_model:
        cfg = cfg.replace(d_model=a.d_model,
                          head_dim=max(32, a.d_model // max(cfg.num_heads, 1)))
    if a.layers:
        cfg = cfg.replace(num_layers=a.layers)
    n = param_count_actual(cfg)
    print(f"[train] {cfg.name}: {n/1e6:.1f}M params, "
          f"batch={a.batch} seq={a.seq}")

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(lr=a.lr)))
    data = SyntheticLM(cfg.vocab_size, a.seq, a.batch, seed=1)

    t0 = time.time()
    losses = []
    for step in range(a.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % a.log_every == 0 or step == a.steps - 1:
            dt = time.time() - t0
            print(f"  step {step:4d} loss {loss:.4f} "
                  f"grad_norm {float(metrics['grad_norm']):.3f} "
                  f"({dt:.1f}s)")
    if a.save:
        save_params(a.save, params, step=a.steps)
        print(f"[train] saved -> {a.save}")
    improved = losses[-1] < losses[0]
    print(f"[train] loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({'improved' if improved else 'NOT improved'})")
    return 0 if improved else 1


if __name__ == "__main__":
    sys.exit(main())
