"""Sharding-aware checkpointing (npz + tree manifest).

Pre-compiled-model semantics from the paper (§3.2): artifacts are written
once after training and loaded by any instance from shared storage; loading
restores per-leaf arrays and (optionally) re-shards onto a mesh.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np

SEP = "::"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(
            p.key if hasattr(p, "key") else str(p.idx) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save_params(path: str, params, step: int = 0, meta: Optional[dict] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(params)
    np.savez(path, **flat)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
        "meta": meta or {},
    }
    with open(path + ".manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)


def load_params(path: str, like, *, shardings=None):
    """`like` provides the pytree structure; `shardings` optionally places
    each leaf on a mesh (device_put with NamedSharding)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(leaves_p))
    out = []
    for (pth, leaf), sh in zip(leaves_p, shard_leaves):
        key = SEP.join(
            p.key if hasattr(p, "key") else str(p.idx) for p in pth)
        arr = data[key]
        if sh is not None:
            arr = jax.device_put(arr, sh)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)
