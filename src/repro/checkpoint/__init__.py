from repro.checkpoint.io import load_params, save_params  # noqa: F401
