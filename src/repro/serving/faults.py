"""Deterministic fault injection + token-exact crash recovery on the
real event-driven data path (paper §3.3-3.5, Fig. 13 — on live engines).

``FaultPlan`` is a seeded schedule of (t, kind, target) chaos events —
node crash, node hang/straggle, link flap — injected through the PR-7
virtual-time event heap, so every chaos run is exactly reproducible:
the same seed yields a bit-identical fault schedule, and (under a
``DeterministicService`` cost model) a bit-identical group event log.

``FaultTolerance`` is the per-group controller that rides the SAME heap
(no new clocks):

  * heartbeat/health-epoch events: every live node reports into
    ``MetaStore.health_report`` on the virtual clock; a node silent past
    the store's ``health_timeout_s`` is ejected at EXACTLY
    ``last_report + timeout`` (a precisely-timestamped eject event);
  * prefill crash: forming requests requeue to healthy peers with
    capped exponential backoff (the §3.5 rejection-forwarding path — no
    scheduler timeout), in-flight transfers sourced at the dead node are
    killed (``TransferScheduler.fail_src``) and their requests re-admitted;
  * decode crash: slots are evicted and every in-flight request is
    re-admitted elsewhere by RE-PREFILLING ``prompt + tokens emitted so
    far`` — riding the prefix-cache / warm-snapshot path (PRs 2/6), so
    recovery is mostly cache hits and, under greedy decoding,
    TOKEN-IDENTICAL: the recovered stream equals the fault-free stream;
  * SLO deadlines: recovery sheds a request whose deadline already
    passed instead of burning compute on a hopeless re-admit;
  * substitute integration: a crashed node reboots (fresh pool+engine —
    its memory is gone) after the ``core.mlops`` substitute-ready
    timeline, re-registers in the MetaStore, is removed from
    ``TransferScheduler.failed_nodes`` (restore_node) and takes traffic;
    an ejected-but-alive straggler rejoins with its prefix cache intact.

``ServeGroup.transfer_stats()`` grows this controller's recovery ledger
(``ft_*`` keys): crashes seen, requests requeued / re-admitted / shed,
recovery wall medians, health-epoch lag.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Iterator, List, Optional, Sequence, Tuple

from repro.core.mlops import FaultRecord, substitute_ready_delay


def _median(xs: Sequence[float]) -> float:
    if not xs:
        return 0.0
    s = sorted(xs)
    n = len(s)
    if n % 2:
        return s[n // 2]
    return 0.5 * (s[n // 2 - 1] + s[n // 2])


# --------------------------------------------------------------- plans
@dataclass(frozen=True)
class FaultEvent:
    """One scheduled chaos event.

    kind:
      * ``crash`` — the node dies; its memory (KV pools, slot state) is
        lost. ``duration`` is the substitute-ready delay; <= 0 uses the
        ``core.mlops`` node_replace timeline.
      * ``hang``  — the node straggles silently for ``duration`` virtual
        seconds (no heartbeats, compute stalled); past the health
        timeout it is ejected, with memory INTACT for a later rejoin.
      * ``flap``  — target ``"src->dst"``: the link drops for
        ``duration``; the in-flight message is retransmitted after.
    """
    t: float
    kind: str          # "crash" | "hang" | "flap"
    target: str        # instance id, or "src->dst" for flap
    duration: float = 0.0


class FaultPlan:
    """An immutable, time-sorted chaos schedule. Equality of seeds means
    equality of schedules: ``FaultPlan.random`` draws only from its own
    ``random.Random(seed)`` over sorted candidate lists."""

    def __init__(self, events: Sequence[FaultEvent] = (),
                 seed: Optional[int] = None):
        self.events: Tuple[FaultEvent, ...] = tuple(
            sorted(events, key=lambda e: (e.t, e.kind, e.target)))
        self.seed = seed

    def __iter__(self) -> Iterator[FaultEvent]:
        return iter(self.events)

    def __len__(self) -> int:
        return len(self.events)

    def __repr__(self) -> str:
        return f"FaultPlan(seed={self.seed}, events={list(self.events)})"

    @classmethod
    def random(cls, seed: int, *, nodes: Sequence[str],
               links: Sequence[Tuple[str, str]] = (),
               t_lo: float = 0.0, t_hi: float = 1.0, n_events: int = 3,
               kinds: Sequence[str] = ("crash", "hang", "flap"),
               hang_s: float = 0.2, crash_recover_s: float = 0.0
               ) -> "FaultPlan":
        rng = random.Random(seed)
        nodes = sorted(nodes)
        links = sorted(links)
        events: List[FaultEvent] = []
        for _ in range(n_events):
            kind = rng.choice(list(kinds))
            t = rng.uniform(t_lo, t_hi)
            if kind == "flap":
                if not links:
                    continue
                src, dst = rng.choice(links)
                events.append(FaultEvent(
                    t, "flap", f"{src}->{dst}",
                    hang_s * rng.uniform(0.5, 1.5)))
            elif kind == "hang":
                events.append(FaultEvent(
                    t, "hang", rng.choice(nodes),
                    hang_s * rng.uniform(0.5, 1.5)))
            else:
                events.append(FaultEvent(
                    t, "crash", rng.choice(nodes), crash_recover_s))
        return cls(events, seed=seed)


@dataclass(frozen=True)
class DeterministicService:
    """Virtual service-time model for reproducible chaos runs: charge a
    deterministic cost per prefill batch / decode step instead of the
    measured wall time, so the whole event log (times included) is
    bit-identical across runs of the same plan. Token values are
    unaffected — the real forwards still run."""
    prefill_base_s: float = 4e-3
    prefill_per_token_s: float = 1e-4
    decode_base_s: float = 2e-3
    decode_per_slot_s: float = 2e-4

    def prefill_batch_s(self, n_tokens: int) -> float:
        return self.prefill_base_s + n_tokens * self.prefill_per_token_s

    def decode_step_s(self, n_slots: int) -> float:
        return self.decode_base_s + n_slots * self.decode_per_slot_s


# ----------------------------------------------------------- controller
class FaultTolerance:
    """Per-ServeGroup fault controller on the group's own event heap.

    Event kinds it owns (dispatched back from ``ServeGroup._dispatch``):
    ``fault`` (a FaultEvent fires), ``hb`` (heartbeat/health epoch),
    ``eject`` (exact-deadline silence check), ``requeue`` (backoff
    retry of a displaced request), ``recover`` (substitute ready /
    straggler resumes)."""

    def __init__(self, group, plan: FaultPlan, *,
                 heartbeat_s: float = 0.05,
                 recover_delay_s: Optional[float] = None,
                 backoff_base_s: float = 0.01,
                 backoff_cap_s: float = 0.5):
        self.group = group
        self.plan = plan
        self.hb_period = float(heartbeat_s)
        # the store's health timeout is the shared per-store config
        # (satellite: threaded from the frontend, virtual seconds)
        self.health_timeout = float(group.meta.health_timeout_s)
        self.recover_delay_s = substitute_ready_delay("node_replace") \
            if recover_delay_s is None else float(recover_delay_s)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        # ---------------------------------------------------- ledger
        self.n_crashes = 0
        self.n_hangs = 0
        self.n_flaps = 0
        self.n_ejected = 0
        self.n_restored = 0
        self.n_requeued = 0        # displaced with NO tokens emitted yet
        self.n_readmitted = 0      # re-prefill of prompt + emitted tokens
        self.n_shed = 0            # hopeless past-deadline requests
        self.recovery_walls: List[float] = []   # eject/crash -> rejoin
        self.hb_lags: List[float] = []          # epoch - oldest report
        self.readmit_hit_tokens = 0
        self.readmit_tokens = 0
        self.faults: List[FaultRecord] = []     # mlops-timeline bridge
        # deterministic chaos action log: (t, action, target)
        self.log: List[Tuple[float, str, str]] = []
        self._n_pending = 0        # outstanding fault/recover/... events
        self._hb_armed = False
        self._eject_armed: set = set()
        self._eject_t: dict = {}   # iid -> time it was ejected
        for ev in plan:
            self._sched(ev.t, "fault", ev)
        if len(plan):
            self._arm_hb(self.hb_period)

    # ------------------------------------------------------- plumbing
    def _sched(self, t: float, kind: str, obj=None):
        self._n_pending += 1
        self.group.schedule(t, kind, obj)

    def _arm_hb(self, t: float):
        if not self._hb_armed:
            self._hb_armed = True
            self.group.schedule(t, "hb", None)

    def _nodes(self):
        g = self.group
        return [("P", n) for n in g.prefills] + \
               [("D", n) for n in g.decodes]

    def _find(self, iid: str):
        for role, node in self._nodes():
            if node.iid == iid:
                return role, node
        return None, None

    def _active(self) -> bool:
        """Chaos still in motion: pending injected/recovery events, or a
        node currently down/straggling. Heartbeats stop when this goes
        false, so an idle timeline drains (serve() terminates)."""
        if self._n_pending > 0:
            return True
        return any(n.crashed or n.ejected
                   or n.hung_until > self.group.vclock
                   for _, n in self._nodes())

    # ------------------------------------------------------- dispatch
    def dispatch(self, kind: str, t: float, obj):
        # windowed retention for the long-run ledgers (identical trims on
        # identical runs, so bit-identical-log comparisons still hold)
        if len(self.log) > 4096:
            del self.log[:-2048]
        if len(self.recovery_walls) > 512:
            del self.recovery_walls[:-256]
        if len(self.faults) > 512:
            del self.faults[:-256]
        if kind == "fault":
            self._n_pending -= 1
            self._fault(t, obj)
        elif kind == "hb":
            self._hb_armed = False
            self._epoch(t)
        elif kind == "eject":
            self._n_pending -= 1
            self._eject_check(t, obj)
        elif kind == "requeue":
            self._n_pending -= 1
            req, attempt = obj
            self._reoffer(t, req, attempt)
        elif kind == "recover":
            self._n_pending -= 1
            what, iid = obj
            (self._reboot if what == "reboot" else self._unhang)(t, iid)

    # --------------------------------------------------------- faults
    def _fault(self, t: float, ev: FaultEvent):
        if ev.kind == "flap":
            self._flap(t, ev)
            return
        role, node = self._find(ev.target)
        if node is None or node.crashed:
            self.log.append((t, f"{ev.kind}-noop", ev.target))
            return
        if ev.kind == "crash":
            self._crash(t, ev, role, node)
        elif ev.kind == "hang":
            self._hang(t, ev, node)
        self._arm_hb(t + self.hb_period)

    def _crash(self, t: float, ev: FaultEvent, role: str, node):
        self.n_crashes += 1
        node.crashed = True
        self.log.append((t, "crash", node.iid))
        # the resident node monitor reports the fault level directly
        # (paper §3.4): detection is immediate, unlike a silent hang
        self.group.meta.health_report(t, node.iid, healthy=False)
        self._evacuate(t, role, node)
        delay = ev.duration if ev.duration > 0 else self.recover_delay_s
        rec = FaultRecord(t, node.iid, "node_replace", t_removed=t)
        self.faults.append(rec)
        self._sched(t + delay, "recover", ("reboot", node.iid))

    def _hang(self, t: float, ev: FaultEvent, node):
        self.n_hangs += 1
        node.hung_until = max(node.hung_until, t + ev.duration)
        node.busy_until = max(node.busy_until, node.hung_until)
        self.log.append((t, "hang", node.iid))
        self._sched(node.hung_until, "recover", ("unhang", node.iid))

    def _flap(self, t: float, ev: FaultEvent):
        self.n_flaps += 1
        self.log.append((t, "flap", ev.target))
        sched = self.group.sched
        if sched is not None and "->" in ev.target:
            src, dst = ev.target.split("->", 1)
            sched.flap_link(src, dst, t, ev.duration)

    # ------------------------------------------------------- ejection
    def _evacuate(self, t: float, role: str, node):
        """Logical removal + work displacement, shared by crash and
        health-timeout ejection. Pool accounting stays exact: every
        displaced rid releases its blocks (idempotent) before the
        request re-enters the ingress path."""
        g = self.group
        g.meta.remove_instance(t, node.iid)
        self.n_ejected += 1
        self._eject_t[node.iid] = t
        displaced = []
        if role == "P":
            if g.sched is not None:
                for job in g.sched.fail_src(node.iid):
                    displaced.append(job.req)
            displaced.extend(node.forming)
            displaced.extend(req for req, _ in node.waiting)
            node.forming = []
            node.waiting = []
            node.staged.clear()
            node.batch_meta.clear()
            node.sse_connections = 0
            for rid in list(node.pool._owned):
                node.pool.release(rid)
        else:
            if g.sched is not None:
                g.sched.fail_node(node.iid)
            # a chunked-prefill absorb job dies with the node: no token
            # streamed yet, so the request requeues from scratch (its
            # partial chunk KV lived only in the dead pool)
            job = getattr(node, "_absorb_job", None)
            if job is not None:
                job.dead = True
                node._absorb_job = None
                node.pool.release(job.req.rid)
                g.absorbs["absorb_displaced"] += 1
                displaced.append(job.req)
            displaced.extend(node.requests.values())
            node.engine.evict_all()
            for rid in list(node.requests):
                node.pool.release(rid)
            node.requests.clear()
        g.event_log.append((t, "eject"))
        if g.sched is not None and not g.sched.idle():
            # jobs the dead dst stranded requeue at the next pump
            g.schedule(t, "pump", None)
        for req in displaced:
            self._reoffer(t, req, 0)

    def _epoch(self, t: float):
        """Heartbeat/health epoch: live nodes report, silent ones get an
        exact-deadline eject check scheduled at last_report + timeout."""
        g = self.group
        for _, node in self._nodes():
            if node.crashed or node.ejected:
                continue
            if node.hung_until > t:
                last = g.meta.silent_since(node.iid)
                if last is not None and node.iid not in self._eject_armed:
                    self._eject_armed.add(node.iid)
                    self._sched(max(t, last + self.health_timeout),
                                "eject", node.iid)
                continue
            g.meta.health_report(t, node.iid)
        reports = [g.meta.silent_since(iid)
                   for iid in g.meta.group_members(g.gid, "P")
                   + g.meta.group_members(g.gid, "D")]
        reports = [r for r in reports if r is not None]
        if reports:
            self.hb_lags.append(max(0.0, t - min(reports)))
            del self.hb_lags[:-512]
        if self._active():
            self._arm_hb(t + self.hb_period)

    def _eject_check(self, t: float, iid: str):
        """Fires at exactly ``last_report + health_timeout_s``; ejects
        only if the node is STILL silent (it may have resumed and
        reported since the check was armed)."""
        self._eject_armed.discard(iid)
        role, node = self._find(iid)
        if node is None or node.crashed or node.ejected:
            return
        last = self.group.meta.silent_since(iid)
        if last is None or node.hung_until <= t \
                or t < last + self.health_timeout - 1e-12:
            return
        node.ejected = True
        self.log.append((t, "eject", iid))
        self._evacuate(t, role, node)

    # ------------------------------------------------------- recovery
    def _reoffer(self, t: float, req, attempt: int):
        """Displaced-request re-entry: requeue (nothing emitted yet) or
        token-exact re-admit (re-prefill prompt + emitted tokens), with
        capped exponential backoff while no healthy peer accepts."""
        if req.done or req.shed:
            return
        if req.slo_deadline_s >= 0.0 and req.submit_t >= 0.0 \
                and t > req.submit_t + req.slo_deadline_s:
            req.shed = True
            req.done = True
            req.finish_t = t
            self.n_shed += 1
            self.log.append((t, "shed", f"rid={req.rid}"))
            return
        g = self.group
        if attempt == 0:
            if req.generated:
                # continuation prompt: the original prompt plus every
                # token emitted so far. Greedy decode makes the
                # re-prefill's next token exactly the token the dead
                # node would have produced — the recovered stream is
                # the fault-free stream
                if not hasattr(req, "_orig_tokens"):
                    req._orig_tokens = list(req.tokens)
                req.tokens = list(req._orig_tokens) + list(req.generated)
                req.readmits += 1
                self.n_readmitted += 1
                best = max((p.prefix_affinity(req) for p in g.prefills
                            if not (p.draining or p.crashed or p.ejected)),
                           default=0)
                self.readmit_hit_tokens += int(best)
                self.readmit_tokens += len(req.tokens)
                self.log.append((t, "readmit", f"rid={req.rid}"))
            else:
                self.n_requeued += 1
                self.log.append((t, "requeue", f"rid={req.rid}"))
        if g.offer(req, t=t):
            self.log.append((t, "placed", f"rid={req.rid}"))
            return
        delay = min(self.backoff_base_s * (2.0 ** attempt),
                    self.backoff_cap_s)
        self._sched(t + delay, "requeue", (req, attempt + 1))

    def _rejoin(self, t: float, node):
        g = self.group
        role = "P" if any(n is node for n in g.prefills) else "D"
        g.meta.gather_instance(t, node.iid, role, g.gid)
        g.meta.health_report(t, node.iid)
        if g.sched is not None:
            g.sched.restore_node(node.iid)
        self.n_restored += 1
        t0 = self._eject_t.pop(node.iid, t)
        self.recovery_walls.append(t - t0)
        g.event_log.append((t, "rejoin"))
        if g.on_capacity is not None:   # fresh capacity: retry pending
            g.on_capacity(t)

    def _reboot(self, t: float, iid: str):
        """Substitute integration for a crash: the node comes back with
        a FRESH pool and engine (its memory died with it), re-registers,
        and is removed from the scheduler's failed set."""
        from repro.serving.cluster import DecodeNode, PrefillNode
        g = self.group
        role, node = self._find(iid)
        if node is None or not node.crashed:
            return
        if role == "P":
            fresh = PrefillNode(iid, g.cfg, g.params, **g.prefill_kwargs)
            g.prefills[g.prefills.index(node)] = fresh
        else:
            fresh = DecodeNode(iid, g.cfg, g.params, **g.decode_kwargs)
            g.decodes[g.decodes.index(node)] = fresh
        # the substitute lands on the same physical iron: its node class
        # (virtual service-time multipliers, pool-lease identity) carries
        fresh.node_class = node.node_class
        fresh.prefill_scale = node.prefill_scale
        fresh.decode_scale = node.decode_scale
        fresh.busy_until = t
        for rec in self.faults:
            if rec.iid == iid and rec.t_substitute_ready < 0.0:
                rec.t_substitute_ready = t
                break
        self.log.append((t, "reboot", iid))
        self._rejoin(t, fresh)

    def _unhang(self, t: float, iid: str):
        """A straggler resumes: if it was ejected it rejoins (prefix
        cache intact — a hang loses no memory); otherwise it simply
        reports again."""
        role, node = self._find(iid)
        if node is None or node.crashed:
            return
        node.hung_until = 0.0
        if node.ejected:
            node.ejected = False
            self.log.append((t, "resume", iid))
            self._rejoin(t, node)
        else:
            self.group.meta.health_report(t, node.iid)
            self.log.append((t, "resume", iid))

    # --------------------------------------------------------- ledger
    def ledger(self) -> dict:
        hit_rate = self.readmit_hit_tokens / self.readmit_tokens \
            if self.readmit_tokens else 0.0
        return {
            "ft_crashes": float(self.n_crashes),
            "ft_hangs": float(self.n_hangs),
            "ft_link_flaps": float(self.n_flaps),
            "ft_ejections": float(self.n_ejected),
            "ft_restores": float(self.n_restored),
            "ft_requests_requeued": float(self.n_requeued),
            "ft_requests_readmitted": float(self.n_readmitted),
            "ft_requests_shed": float(self.n_shed),
            "ft_recovery_wall_median_s": _median(self.recovery_walls),
            "ft_health_epoch_lag_median_s": _median(self.hb_lags),
            "ft_readmit_prefix_hit_rate": hit_rate,
        }
