"""Scenario-aware multi-group serving frontend on the REAL data path.

This is the paper's fine-grained P/D organization (§3.2-3.5) running on
actual engines rather than the discrete-event simulator:

  ClusterFrontend (gateway)
    -> ServeGroup["svcA/chat"]: PrefillNode* -> KV transfer -> DecodeNode*
    -> ServeGroup["svcA/summ"]: PrefillNode* -> KV transfer -> DecodeNode*
    ...

Each ServeGroup binds one scenario tag to its own prefill/decode nodes
registered in the MetaStore (the Zookeeper role), so prefill/decode
processing stays similar within a group — and the group's prefill pools
keep that scenario's prefix KVCaches hot (§2.2.1): ingress prefers the
node with the longest cached prefix (suffix-only prefill on a hit, see
serving/kvcache.py), then least SSE connections, with on-demand
rejection forwarding across groups when the home group is saturated
(§3.5 fallback), else the request waits at the gateway.
ServeGroup.prefix_stats() aggregates hit-rate / reused-token counters.

The serving core is TICKLESS: the TransferScheduler's virtual-time
event queue is the spine of the group. Request arrivals, prefill-batch
completions, per-layer KV segment landings, decode steps, drained role
flips and prefix-cache evictions are all timestamped events drained in
nondecreasing virtual time (ClusterFrontend.serve merges every group's
frontier plus the gateway arrival queue onto one shared timeline), so
TTFT/TPOT are ledgered in virtual SECONDS — the goodput currency of the
open-loop benchmarks — not in synchronous tick counts. The staged
``tick()`` survives only as a compatibility shim that pumps the same
event handlers in the legacy stage order (prefill -> transfer -> pump
-> decode) to the current deadline; both paths are token-identical
(greedy decode is scheduling-order-invariant, pinned by test).

KV hand-off runs through the overlapped layer-wise transfer pipeline by
default (serving/transfer_sched.py, §3.6 Fig. 10): prefill streams
per-layer KV into the scheduler, decode admission fires when the last
segment lands, and per-group transfer_stats() ledgers admission waits,
retries and failover requeues. ``overlap_transfer=False`` restores the
blocking transfer (charged on the same event timeline).

A RatioAdjuster performs runtime P/D ratio adjustment per group: it
compares the deployed ratio against the Eq.1 optimum
(repro.core.perf_model.optimal_ratio) on a profiled-in-advance
InstanceProfile or on the group's own observed prefill/decode timings,
gated by observed queue/TTFT pressure, then flips ONE node between P
and D roles. A flip drains the node first (logical removal: no new
traffic, in-flight work completes), then swaps the
PrefillNode/DecodeNode wrapper over the SAME shared params and
re-registers the instance in the MetaStore — PDGroup's dynamic RoCE
reconstruction (core.group), but on real engines.
"""
from __future__ import annotations

import heapq
import itertools
import random
import time
import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax

from repro.core.perf_model import InstanceProfile, optimal_ratio
from repro.core.transfer import KVTransferEngine, LinkModel
from repro.core.zookeeper import MetaStore
from repro.models.config import ModelConfig
from repro.models.params import init_params
from repro.serving.cluster import DecodeNode, PrefillNode, ServeRequest
from repro.serving.engine import prefill_compile_count
from repro.serving.transfer_sched import (TransferJob, TransferScheduler,
                                          state_payload_nbytes)


def _mean(xs: Sequence[float]) -> float:
    return sum(xs) / len(xs) if xs else 0.0


def _median(xs: Sequence[float]) -> float:
    """True median: even-length windows average the two middle samples
    (the upper-middle shortcut biased Eq.1 inputs and the *_median_s
    telemetry high)."""
    if not xs:
        return 0.0
    s = sorted(xs)
    n = len(s)
    if n % 2:
        return s[n // 2]
    return 0.5 * (s[n // 2 - 1] + s[n // 2])


@dataclass
class AbsorbJob:
    """One chunked prefill running ON a decode node (DynaServe-style
    elasticity): ``chunks`` is the engine's ``iter_chunks`` generator,
    stepped one chunk per ``absorb`` event so decode steps interleave
    between chunks on the virtual timeline. The node's pool blocks for
    prompt + generation were reserved at job start; the final chunk's
    stitched KV is written there and the request admits in place — no
    transfer, the KV is already home."""
    req: ServeRequest
    node: DecodeNode
    chunks: object                  # PrefillEngine.iter_chunks generator
    n_left: int                     # chunks not yet run
    out: object = None              # latest (cumulative) PrefillOutput
    dead: bool = False              # node crashed/ejected under the job


class ServeGroup:
    """One scenario-bound P/D group on real engines (paper §3.2-3.3).

    Internally event-driven: ``self.events`` is a (t, seq, kind, node)
    min-heap sharing one virtual timeline with the TransferScheduler's
    link events. Event kinds:

      * ``batch``   — a prefill node runs its formed batch (charging its
                      MEASURED wall time as virtual seconds);
      * ``xfer``    — hand prefilled requests to decode (begin pipelined
                      transfer, or pay the blocking stall inline);
      * ``step``    — one continuous-batching decode iteration
                      (self-rescheduling while the node has requests);
      * ``segment`` — a per-layer KV stripe (or trailing state payload)
                      landed on a link (drained via scheduler pump);
      * ``pump``    — bare scheduler retry point (waiting_dst jobs);
      * ``evict``   — a prefix-cache block eviction (observability).

    ``event_log`` records drained events as (t, kind), nondecreasing in
    t while the tickless loop drives the group (property-tested)."""

    def __init__(self, gid: str, scenario: str, cfg: ModelConfig, params,
                 meta: MetaStore, xfer: KVTransferEngine, *,
                 n_prefill: int = 1, n_decode: int = 1,
                 transfer_mode: str = "block_free",
                 overlap_transfer: bool = True,
                 iid_prefix: Optional[str] = None,
                 prefill_kwargs: Optional[dict] = None,
                 decode_kwargs: Optional[dict] = None,
                 spec=None, fault_plan=None,
                 fault_kwargs: Optional[dict] = None,
                 service_model=None,
                 absorb_prefill: bool = False,
                 absorb_chunk_tokens: int = 16):
        self.gid = gid
        self.scenario = scenario
        self.cfg = cfg
        self.params = params
        self.meta = meta
        self.xfer = xfer
        self.transfer_mode = transfer_mode
        # overlapped layer-wise transfer pipeline (Fig. 10): decode
        # admission is event-driven (fires when the last layer lands)
        # instead of blocking inside the transfer hand-off
        self.overlap_transfer = bool(overlap_transfer)
        self.sched: Optional[TransferScheduler] = TransferScheduler(
            xfer.link, seed=zlib.crc32(gid.encode()) & 0xFFFF,
            pick_dst=self._sched_pick) if overlap_transfer else None
        self.vclock = 0.0                          # virtual seconds
        self.blocking_waits: List[float] = []      # sync-mode D2D stalls
        self.n_blocking_admits = 0                 # monotonic (list trims)
        self._blk_free_t = 0.0                     # blocking-mode link busy
        self.prefill_kwargs = dict(prefill_kwargs or {})
        self.decode_kwargs = dict(decode_kwargs or {})
        # group-wide speculative draft binding: every decode node this
        # group ever constructs (including P->D role flips) runs the
        # same scenario-chosen draft
        if spec is not None:
            self.decode_kwargs.setdefault("spec", spec)
        self._prefix = f"{gid}/" if iid_prefix is None else iid_prefix
        self._n_p = itertools.count()
        self._n_d = itertools.count()
        meta.register_group(gid, scenario)
        self.prefills: List[PrefillNode] = [
            self._new_prefill(0.0) for _ in range(n_prefill)]
        self.decodes: List[DecodeNode] = [
            self._new_decode(0.0) for _ in range(n_decode)]
        self.rejections = 0            # requests no node would take (§3.5)
        self.probe_rejections = 0      # per-node placement probes that failed
        self.n_accepted = 0
        self.accepted: List[int] = []              # recent rids admitted
        # (t, old_iid, new_iid, "P->D" | "D->P"); t is the tick number
        # under the staged shim, virtual seconds under the event loop.
        # The list keeps a bounded window; n_flips is the monotonic count
        self.flips: List[Tuple[float, str, str, str]] = []
        self.n_flips = 0
        # ------------------------------------- autoscale / elasticity
        self.scaler = None             # AutoScaler back-ref (scale events)
        self.scale_op = None           # in-flight ScaleOp (adjuster yields)
        self.absorb_prefill = bool(absorb_prefill)
        self.absorb_chunk_tokens = int(absorb_chunk_tokens)
        self.absorb_retry_s = 2e-3     # slot-wait poll for the final chunk
        self.absorbs: Dict[str, int] = {
            "absorb_requests": 0, "absorb_chunks": 0,
            "absorb_tokens": 0, "absorb_displaced": 0}
        self.on_displaced = None       # gateway hook: crashed absorb jobs
        # observed stats feeding the ratio adjuster; consumers only read
        # bounded tails, so the event handlers trim these to a window
        self.prefill_batch_s: List[float] = []     # wall time per batch
        self.decode_step_s: List[float] = []       # wall time per step
        self.gen_tokens: List[int] = []            # admitted target lengths
        self.ttft_s: List[float] = []              # submit -> first token
        # ------------------------------------------------- event core
        self.events: List[Tuple[float, int, str, object]] = []
        self._eseq = itertools.count()
        self.event_log: List[Tuple[float, str]] = []
        self._tickless = False         # True while ClusterFrontend.serve
        self.on_capacity = None        # gateway hook: capacity may have freed
        # ------------------------------------------- fault tolerance
        # deterministic virtual service-time model (faults.py): when
        # set, batch/step events charge model costs instead of measured
        # wall time, making the whole event log bit-reproducible
        self.service_model = service_model
        self.ft = None                 # FaultTolerance controller
        if fault_plan is not None:
            from repro.serving.faults import FaultTolerance
            self.ft = FaultTolerance(self, fault_plan,
                                     **(fault_kwargs or {}))

    # ------------------------------------------------- node construction
    def _set_class(self, node, ncls):
        if ncls is not None:
            node.node_class = ncls.name
            node.prefill_scale = ncls.prefill_scale
            node.decode_scale = ncls.decode_scale
        return node

    def _new_prefill(self, t: float, *, iid: Optional[str] = None,
                     ncls=None) -> PrefillNode:
        iid = iid or f"{self._prefix}P{next(self._n_p)}"
        node = PrefillNode(iid, self.cfg, self.params,
                           **self.prefill_kwargs)
        self.meta.gather_instance(t, iid, "P", self.gid)
        self.meta.health_report(t, iid)
        return self._set_class(node, ncls)

    def _new_decode(self, t: float, *, iid: Optional[str] = None,
                    ncls=None) -> DecodeNode:
        iid = iid or f"{self._prefix}D{next(self._n_d)}"
        node = DecodeNode(iid, self.cfg, self.params, **self.decode_kwargs)
        self.meta.gather_instance(t, iid, "D", self.gid)
        self.meta.health_report(t, iid)
        return self._set_class(node, ncls)

    # ---------------------------------------- autoscale node lifecycle
    def find_node(self, iid: str):
        for n in self.prefills + self.decodes:
            if n.iid == iid:
                return n
        return None

    def add_node(self, t: float, role: str, *, iid: Optional[str] = None,
                 ncls=None):
        """Provisioned capacity joins the group (the terminal event of a
        scale-up op): the node registers in the MetaStore, fresh
        capacity retries stranded hand-offs and pending gateway work."""
        if role == "P":
            node = self._new_prefill(t, iid=iid, ncls=ncls)
            node.busy_until = t
            self.prefills.append(node)
        else:
            node = self._new_decode(t, iid=iid, ncls=ncls)
            node.busy_until = t
            self.decodes.append(node)
        self.event_log.append((t, "scale"))
        if self._tickless:
            for p in self.prefills:
                if p.waiting:
                    self.schedule(t, "xfer", p)
            if self.sched is not None and not self.sched.idle():
                self.schedule(t, "pump", None)
        if self.on_capacity is not None:
            self.on_capacity(t)
        return node

    def node_drained(self, node) -> bool:
        """No in-flight work left on a draining node (decommission can
        complete)."""
        if node in self.prefills:
            return not (node.forming or node.waiting)
        busy = bool(node.requests) or node._absorb_job is not None
        if self.sched is not None and self.sched.pending_for(node.iid):
            busy = True
        return not busy

    def remove_node(self, t: float, node):
        """Decommission a drained node out of the group (back to the
        shared pool — the AutoScaler owns the pool-side accounting)."""
        if node in self.prefills:
            self.prefills.remove(node)
        elif node in self.decodes:
            self.decodes.remove(node)
        self.meta.remove_instance(t, node.iid)
        self.event_log.append((t, "scale"))

    @property
    def ratio(self) -> Tuple[int, int]:
        return len(self.prefills), len(self.decodes)

    def load(self) -> int:
        """Requests currently anywhere in this group's pipeline (forming
        or prefilled-but-unhanded, in-flight transfer, decoding) — the
        gateway's least-loaded fallback signal for unknown scenarios."""
        n = sum(len(p.forming) + len(p.waiting) for p in self.prefills)
        n += sum(len(d.requests) for d in self.decodes)
        n += sum(1 for d in self.decodes if d._absorb_job is not None)
        if self.sched is not None:
            n += len(self.sched.jobs) + len(self.sched.waiting)
        return n

    # ------------------------------- ingress (on-demand rejection, §3.5)
    def offer(self, req: ServeRequest, t: Optional[float] = None) -> bool:
        """Place ``req`` on a prefill node. ONE rejection is counted per
        request no node accepts (per-node probe failures are ledgered
        separately — the old per-probe count inflated §3.5 forwarding
        stats by up to n_prefill x). In event mode (``t`` given) a batch
        event is scheduled for the accepting node."""
        # prefix affinity first (a node holding the request's prefix
        # KVCache hot serves it suffix-only), then least SSE connections
        for p in sorted(self.prefills,
                        key=lambda x: (-x.prefix_affinity(req),
                                       x.sse_connections)):
            if p.draining or p.crashed or p.ejected:
                continue   # logical removal: not a rejection
            if p.offer(req):
                self.accepted.append(req.rid)
                self.n_accepted += 1
                if t is not None:
                    self._schedule_batch(p, max(t, p.busy_until))
                return True
            self.probe_rejections += 1
        self.rejections += 1
        return False

    def try_absorb(self, req: ServeRequest, t: float) -> bool:
        """Overload elasticity (DynaServe-style): when every prefill node
        rejected ``req``, an idle-capacity decode node can absorb it as
        CHUNKED prefill — the absorber engine (same params) runs
        ``prefix_align``-sized chunks between its decode steps, and the
        final chunk's stitched KV lands directly in the decode pool (no
        transfer). Token-identical to a monolithic prefill by the warm-
        continuation contracts (pinned per family in tests)."""
        if not self.absorb_prefill or t is None:
            return False
        if req.gw_attempts < 1:
            # second-chance rung: one backoff round-trip filters
            # transient prefill-full bursts — only sustained overload
            # spills prefill work onto the decode side
            return False
        total = len(req.tokens) + req.max_new_tokens + 1
        # a chunk's service wall (>= the per-batch base) dwarfs the TPOT
        # budget of co-resident decodes, so only a node with NO live
        # decode work may start absorbing
        cands = [d for d in self.decodes
                 if not (d.draining or d.crashed or d.ejected)
                 and d._absorb_job is None
                 and not d.requests
                 and not (self.sched and self.sched.pending_for(d.iid))
                 and self._free_capacity(d) > 0]
        for d in sorted(cands, key=lambda d: (len(d.requests), d.iid)):
            eng = d.absorber()
            if not eng.supports_prefix_reuse:
                return False           # family serves cold-only: no chunks
            if d.pool.free_blocks < d.pool.blocks_for_tokens(total):
                continue
            d.pool.alloc(req.rid, total)   # reserve prompt + gen room NOW
            cuts = eng.chunk_bounds(len(req.tokens),
                                    self.absorb_chunk_tokens)
            job = AbsorbJob(
                req=req, node=d,
                chunks=eng.iter_chunks(
                    req.tokens, chunk_tokens=self.absorb_chunk_tokens,
                    frames=req.frames),
                n_left=len(cuts) + 1)
            d._absorb_job = job
            self.accepted.append(req.rid)
            self.n_accepted += 1
            self.absorbs["absorb_requests"] += 1
            self.schedule(max(t, d.busy_until), "absorb", job)
            return True
        return False

    # ------------------------------------- transfer-pipeline callbacks
    def _free_capacity(self, d: DecodeNode) -> int:
        """Decode slots not yet spoken for: free minus in-flight transfer
        jobs minus an active absorbed prefill (its final chunk admits in
        place, so it holds one slot claim from the moment it starts)."""
        pend = self.sched.pending_for(d.iid) if self.sched else 0
        absorb = 1 if d._absorb_job is not None else 0
        return d.free_slot_count() - pend - absorb

    def _pick_decode(self, exclude: Tuple[DecodeNode, ...] = ()
                     ) -> Optional[DecodeNode]:
        cands = [d for d in self.decodes
                 if d not in exclude and d.can_admit()
                 and self._free_capacity(d) > 0
                 and not (self.sched
                          and d.iid in self.sched.failed_nodes)]
        return min(cands,
                   key=lambda d: len(d.requests)
                   + (self.sched.pending_for(d.iid) if self.sched else 0),
                   default=None)

    def _sched_pick(self, job: TransferJob) -> Optional[DecodeNode]:
        """Fallback target for a requeued job: prefer ANOTHER node than
        the one that drained/failed/conflicted; same node only if it is
        healthy and the sole candidate."""
        tgt = self._pick_decode(exclude=(job.dst,))
        return tgt if tgt is not None else self._pick_decode()

    def _on_admit(self, job: TransferJob):
        job.dst.finish_admit(job.req, job.out)
        self.gen_tokens.append(job.req.max_new_tokens)
        if self._tickless:
            self._schedule_step(job.dst,
                                max(job.admitted_t, job.dst.busy_until))

    # ------------------------------------------------------- event core
    def schedule(self, t: float, kind: str, obj: object = None):
        heapq.heappush(self.events, (t, next(self._eseq), kind, obj))

    def _schedule_batch(self, p: PrefillNode, t: float):
        if p._batch_evt:
            return
        p._batch_evt = True
        self.schedule(t, "batch", p)

    def _schedule_step(self, d: DecodeNode, t: float):
        if d._step_evt:
            return
        d._step_evt = True
        self.schedule(t, "step", d)

    def next_time(self) -> Optional[float]:
        """Earliest pending event on this group's timeline (queued group
        events and transfer-link landings)."""
        t = self.events[0][0] if self.events else None
        if self.sched is not None and not self.sched.idle():
            ts = self.sched.next_event()
            if ts is not None and (t is None or ts < t):
                t = ts
        return t

    def advance(self, until: float):
        """Drain group events and link-segment landings in global
        nondecreasing virtual-time order, up to and including ``until``.
        This is the tickless hot loop; the staged shim reuses the same
        handlers through _drain_queued."""
        for _ in range(1_000_000):
            t_ev = self.events[0][0] if self.events else None
            t_sc = None
            if self.sched is not None and not self.sched.idle():
                t_sc = self.sched.next_event()
            if t_sc is not None and t_sc <= until \
                    and (t_ev is None or t_sc <= t_ev):
                self.vclock = max(self.vclock, t_sc)
                self.event_log.append((t_sc, "segment"))
                self.sched.pump(t_sc)
            elif t_ev is not None and t_ev <= until:
                t, _, kind, obj = heapq.heappop(self.events)
                if self.sched is not None:
                    self.sched.pump(t)
                self.vclock = max(self.vclock, t)
                self.event_log.append((t, kind))
                self._dispatch(kind, t, obj)
            else:
                return
        raise RuntimeError(f"event loop runaway in group {self.gid}")

    def _drain_queued(self):
        """Pop every queued group event in time order (staged shim:
        events never outrun the handlers that scheduled them), pumping
        the transfer scheduler in lockstep so segment landings and
        admissions interleave at their true times."""
        while self.events:
            t, _, kind, obj = heapq.heappop(self.events)
            if self.sched is not None:
                self.sched.pump(t)
            self.vclock = max(self.vclock, t)
            self.event_log.append((t, kind))
            self._dispatch(kind, t, obj)

    def _dispatch(self, kind: str, t: float, obj: object):
        if kind == "batch":
            self._ev_batch(t, obj)
        elif kind == "xfer":
            self._ev_xfer(t, obj)
        elif kind == "step":
            self._ev_step(t, obj)
        elif kind == "absorb":
            self._ev_absorb(t, obj)
        elif kind == "scale":
            if self.scaler is not None:
                self.scaler.on_event(t, self, obj)
        elif kind in ("fault", "hb", "eject", "requeue", "recover"):
            if self.ft is not None:
                self.ft.dispatch(kind, t, obj)
        # "pump": the pre-dispatch pump already retried waiting jobs;
        # "evict"/"segment" are ledger-only kinds

    # ------------------------------------------------------- handlers
    def _ev_batch(self, t: float, p: PrefillNode):
        """Run a prefill node's formed batch at virtual time ``t``; the
        node is busy until t + measured wall seconds, TTFT ends (first
        token streams) at batch completion, and the transfer hand-off is
        scheduled there."""
        p._batch_evt = False
        if not p.forming:
            return
        if p.busy_until > t + 1e-12:       # mid-batch: wait for the node
            self._schedule_batch(p, p.busy_until)
            return
        batch_rids = [r.rid for r in p.forming]
        batch_tokens = sum(len(r.tokens) for r in p.forming)
        t0 = time.perf_counter()
        ready = p.run_batch(collect_layers=self.overlap_transfer)
        w = time.perf_counter() - t0
        if self.service_model is not None:
            # deterministic chaos runs: charge the model's virtual cost,
            # not the jittery measured wall time
            w = self.service_model.prefill_batch_s(batch_tokens)
        # heterogeneous node classes: the class scales the VIRTUAL
        # service time only (token streams are class-invariant)
        w *= p.prefill_scale
        self.prefill_batch_s.append(w)
        done = t + w
        p.busy_until = done
        self.vclock = max(self.vclock, done)
        if self.sched is not None:       # only consumer of the meta
            for rid in batch_rids:
                p.batch_meta[rid] = (t, w)
        for req, _ in ready:
            # a crash-displaced re-admit keeps its ORIGINAL first-token
            # stamp: TTFT ended when the first prefill streamed it
            if req.first_token_t < 0.0:
                req.first_token_t = done
                if req.submit_t >= 0.0:
                    self.ttft_s.append(max(0.0, done - req.submit_t))
        self._note_evictions(p, t)
        # overlapped: the engine streams layers DURING the compute
        # window, so the hand-off (scheduler begin) is stamped at batch
        # start and segments land under the window (Fig. 10); blocking
        # transfer can only move the final KV at batch completion
        self.schedule(t if self.sched is not None else done, "xfer", p)
        if self.on_capacity is not None:   # forming slots freed
            self.on_capacity(done)
        self._trim_hists()

    def _ev_xfer(self, t: float, p: PrefillNode):
        """Hand prefilled requests to decode: pipelined transfer begin
        (overlapped) or inline blocking admission charging the D2D stall
        — including the recurrent-state payload of attn-free/SSM
        requests, whose ``out.k is None`` previously ledgered a free
        transfer."""
        if not p.waiting:
            return
        for pair in [pr for pr in p.waiting
                     if len(pr[0].generated) >= pr[0].max_new_tokens + 1]:
            # budget exhausted at prefill (max_new=0 scoring-style
            # requests): nothing to decode, so nothing to transfer —
            # finish where the first token streamed
            req, _ = pair
            p.waiting.remove(pair)
            req.done = True
            req.finish_t = max(t, req.first_token_t)
            p.pool.release(req.rid)
            p.batch_meta.pop(req.rid, None)
            p.staged.pop(req.rid, None)
            self.gen_tokens.append(req.max_new_tokens)
        remaining = []
        moved = False
        for req, out in p.waiting:
            tgt = self._pick_decode()
            if tgt is None:
                remaining.append((req, out))
                continue
            if self.sched is not None:
                t0v, w = p.batch_meta.pop(req.rid, (t, 0.0))
                self.sched.begin(
                    req, out, src_iid=p.iid, dst=tgt, t_start=t0v,
                    compute_s=w, payloads=p.staged.pop(req.rid, None),
                    fracs=p.engine.layer_fractions() or None,
                    on_admit=self._on_admit)
                p.pool.release(req.rid)
            else:
                tgt.admit(req, out, p.pool, self.xfer,
                          mode=self.transfer_mode)
                stall = self.xfer.stats[-1].time_s if out.k is not None \
                    else 0.0
                state_b = state_payload_nbytes(out)
                if state_b:
                    # the mamba state / cross KV crosses the same link:
                    # state-only payloads pay wire time too
                    stall += self.xfer.link.time(state_b, 1)
                self.blocking_waits.append(stall)
                self.n_blocking_admits += 1
                start = max(t, self._blk_free_t)
                admitted = start + stall
                self._blk_free_t = admitted
                self.vclock = max(self.vclock, admitted)
                self.gen_tokens.append(req.max_new_tokens)
                if self._tickless:
                    self._schedule_step(tgt, max(admitted, tgt.busy_until))
            p.sse_connections -= 1
            moved = True
        p.waiting = remaining
        if moved and self.on_capacity is not None:
            self.on_capacity(t)

    def _ev_step(self, t: float, d: DecodeNode):
        """One decode iteration at virtual time ``t``; in tickless mode
        the node self-reschedules while it has requests, and completions
        retry the transfer hand-off (freed slots) at once."""
        d._step_evt = False
        if not d.requests:
            return
        if d.busy_until > t + 1e-12:
            self._schedule_step(d, d.busy_until)
            return
        n_slots = len(d.requests)
        t0 = time.perf_counter()
        finished = d.step()
        w = time.perf_counter() - t0
        if self.service_model is not None:
            w = self.service_model.decode_step_s(n_slots)
        w *= d.decode_scale
        self.decode_step_s.append(w)
        done = t + w
        d.busy_until = done
        self.vclock = max(self.vclock, done)
        for req in finished:
            req.finish_t = done
        if self._tickless:
            if d.requests:
                self._schedule_step(d, done)
            if finished:
                for p in self.prefills:
                    if p.waiting:
                        self.schedule(done, "xfer", p)
                if self.sched is not None and not self.sched.idle():
                    self.schedule(done, "pump", None)
        self._trim_hists()

    def _ev_absorb(self, t: float, job: AbsorbJob):
        """Run ONE chunk of an absorbed prefill on its decode node at
        virtual time ``t``: the chunk charges the node's busy window
        (scaled by its class's prefill cost), so decode steps and
        further chunks interleave on the heap. The final chunk writes
        the full stitched KV into the node's own pool and admits the
        request in place — TTFT ends here."""
        d = job.node
        req = job.req
        if job.dead:
            return                      # crash evacuation re-offered it
        if d.crashed or d.ejected:
            # no fault controller claimed the job (ft-less run): requeue
            # through the gateway's displaced hook
            job.dead = True
            d._absorb_job = None
            d.pool.release(req.rid)
            self.absorbs["absorb_displaced"] += 1
            if self.on_displaced is not None:
                self.on_displaced(req, t)
            elif not self.offer(req, t=t):
                pass                    # dropped back to caller's ledger
            return
        if d.busy_until > t + 1e-12:
            self.schedule(d.busy_until, "absorb", job)
            return
        if job.n_left == 1 and not d.engine.free_slots() \
                and req.max_new_tokens >= 1:
            # the last chunk ends in an in-place admit, and decode
            # traffic filled every slot since the job started: hold the
            # final chunk until a step retires a request (poll — the
            # reserved pool blocks keep the admit itself safe)
            self.schedule(t + self.absorb_retry_s, "absorb", job)
            return
        t0 = time.perf_counter()
        n_chunk, out = next(job.chunks)
        w = time.perf_counter() - t0
        if self.service_model is not None:
            w = self.service_model.prefill_batch_s(n_chunk)
        w *= d.prefill_scale            # decode iron runs prefill slower
        done = t + w
        d.busy_until = done
        self.vclock = max(self.vclock, done)
        job.out = out
        job.n_left -= 1
        self.absorbs["absorb_chunks"] += 1
        self.absorbs["absorb_tokens"] += int(n_chunk)
        if job.n_left > 0:
            self.schedule(done, "absorb", job)
            return
        # final chunk: KV home, admit in place, first token streams
        bs = d.pool.block_size
        if out.k is not None:
            d.pool.write_prefill(
                d.pool.owned(req.rid)[: (out.prompt_len + bs - 1) // bs],
                out.k, out.v)
        if req.first_token_t < 0.0:
            req.first_token_t = done
            if req.submit_t >= 0.0:
                self.ttft_s.append(max(0.0, done - req.submit_t))
        req.generated.append(out.first_token)
        if req.on_token:
            req.on_token(out.first_token)
        self.gen_tokens.append(req.max_new_tokens)
        d._absorb_job = None
        if len(req.generated) >= req.max_new_tokens + 1:
            # prefill-complete budget: nothing to decode — finish in
            # place, the reserved blocks free without touching a slot
            req.done = True
            req.finish_t = done
            d.pool.release(req.rid)
            self._trim_hists()
            return
        d.finish_admit(req, out)
        if self._tickless:
            self._schedule_step(d, done)
        self._trim_hists()

    def _note_evictions(self, p: PrefillNode, t: float):
        new = p.pool.evictions - p._evictions_seen
        p._evictions_seen = p.pool.evictions
        for _ in range(int(new)):
            self.event_log.append((t, "evict"))

    def _trim_hists(self):
        for hist in (self.prefill_batch_s, self.decode_step_s,
                     self.gen_tokens, self.ttft_s, self.accepted,
                     self.blocking_waits):
            if len(hist) > 512:
                del hist[:-256]
        if len(self.event_log) > 4096:
            del self.event_log[:-2048]

    # ------------------------------------------ staged compatibility shim
    def tick(self, tick_no: int):
        """Legacy staged step, now a shim over the event core: enqueue
        batch/transfer events at the current frontier, drain them (with
        the scheduler pumped in lockstep), take ONE decode iteration per
        busy node, then — replacing the old spinning-ticks hack — jump
        the frontier to the next pending event if nothing advanced."""
        if self.ft is not None:
            # _drain_queued pops queued events regardless of time, so a
            # future-dated fault/heartbeat would fire early and corrupt
            # the deterministic chaos timeline
            raise RuntimeError(
                "fault injection requires the tickless event loop; the "
                "staged tick() shim cannot honor future-dated fault "
                "events")
        self._tickless = False
        vt0 = self.vclock
        for p in self.prefills:
            if p.forming:
                self._schedule_batch(p, max(self.vclock, p.busy_until))
            elif p.waiting:
                self.schedule(self.vclock, "xfer", p)
        self._drain_queued()
        # completed last layers fire decode admission
        if self.sched is not None:
            self.sched.pump(self.vclock)
        for d in self.decodes:
            if d.requests:
                self.event_log.append((self.vclock, "step"))
                self._ev_step(self.vclock, d)
        # event-frontier progress guarantee: transfers still in flight
        # with the group otherwise idle advance to the next link event
        # instead of spinning ticks
        if self.vclock <= vt0:
            nxt = self.next_time()
            if nxt is not None:
                self.advance(nxt)
        self._trim_hists()
        self._complete_flips(tick_no)

    # --------------------------------- runtime role flips (§3.3 on real)
    def draining_nodes(self) -> List[str]:
        return [n.iid for n in self.prefills + self.decodes if n.draining]

    def request_flip(self, src_role: str, *, min_each: int = 1
                     ) -> Optional[str]:
        """Mark the least-loaded node of `src_role` as draining; the swap
        itself happens in _complete_flips once its in-flight work is
        done. Returns the draining iid, or None if the group cannot give
        up a node (min_each single-point-failure floor)."""
        if src_role == "P":
            live = [p for p in self.prefills
                    if not (p.draining or p.crashed or p.ejected)]
            if len(live) <= min_each:
                return None
            node = min(live, key=lambda p: (len(p.forming) + len(p.waiting),
                                            p.iid))
        else:
            live = [d for d in self.decodes
                    if not (d.draining or d.crashed or d.ejected)]
            if len(live) <= min_each:
                return None
            node = min(live, key=lambda d: (len(d.requests), d.iid))
        node.draining = True
        return node.iid

    def _complete_flips(self, t: float):
        """``t``: tick number under the staged shim, virtual seconds in
        event mode (flip completion is itself a timestamped event)."""
        tf = float(t)
        flipped = False
        # decommissioning nodes drain OUT of the group (autoscale), not
        # into the opposite role — the scaler's re-check owns them
        for p in [x for x in self.prefills
                  if x.draining and not x.decommissioning]:
            if p.forming or p.waiting:
                continue   # in-flight prefill work must complete first
            self.prefills.remove(p)
            self.meta.remove_instance(tf, p.iid)
            d = self._new_decode(tf)
            d.node_class = p.node_class        # same iron, new role
            d.prefill_scale = p.prefill_scale
            d.decode_scale = p.decode_scale
            self.flips.append((t, p.iid, d.iid, "P->D"))
            self.n_flips += 1
            self.decodes.append(d)
            flipped = True
        for d in [x for x in self.decodes
                  if x.draining and not x.decommissioning]:
            if d.requests or d._absorb_job is not None \
                    or (self.sched is not None
                        and self.sched.pending_for(d.iid)):
                continue   # in-flight decodes/transfers must clear first
            self.decodes.remove(d)
            self.meta.remove_instance(tf, d.iid)
            p = self._new_prefill(tf)
            p.node_class = d.node_class
            p.prefill_scale = d.prefill_scale
            p.decode_scale = d.decode_scale
            self.flips.append((t, d.iid, p.iid, "D->P"))
            self.n_flips += 1
            self.prefills.append(p)
            flipped = True
        if len(self.flips) > 512:
            del self.flips[:-256]
        if flipped:
            self.event_log.append((tf, "flip"))
            if self._tickless:
                # fresh capacity: retry queued hand-offs and stranded jobs
                for p in self.prefills:
                    if p.waiting:
                        self.schedule(tf, "xfer", p)
                if self.sched is not None and not self.sched.idle():
                    self.schedule(tf, "pump", None)
            if self.on_capacity is not None:
                self.on_capacity(tf)

    # ------------------------------------------------------------- stats
    def observed_profile(self, *, min_samples: int = 3
                         ) -> Optional[InstanceProfile]:
        """InstanceProfile from this group's own measured timings, for
        Eq.1 when no profiled-in-advance numbers are supplied."""
        if (len(self.prefill_batch_s) < min_samples
                or len(self.decode_step_s) < min_samples):
            return None
        b_p = max(p.batch_size for p in self.prefills) if self.prefills \
            else 4
        b_d = max(d.engine.max_slots for d in self.decodes) if self.decodes \
            else 8
        # medians: first samples per shape carry one-time JIT compile
        # cost that would otherwise dominate the window
        return InstanceProfile(
            ttft_bs=max(_median(self.prefill_batch_s[-32:]), 1e-9), b_p=b_p,
            r_pre=1.0, tpot_bs=max(_median(self.decode_step_s[-32:]), 1e-9),
            b_d=b_d, gen_tokens=max(_mean(self.gen_tokens[-64:]), 1.0),
            xi=0.0)

    def prefix_stats(self) -> Dict[str, float]:
        """Aggregated prefix-reuse stats over this group's live prefill
        nodes (per-scenario index: routing affinity keeps a scenario's
        prefixes hot inside its own group, Fig. 1b)."""
        agg = {"lookups": 0.0, "hits": 0.0, "hit_tokens": 0.0,
               "evictions": 0.0, "cow_copies": 0.0,
               "compute_tokens": 0.0, "reused_tokens": 0.0,
               "snap_hits": 0.0, "snap_misses": 0.0,
               "snap_stores": 0.0, "snap_bytes": 0.0,
               "state_restores": 0.0}
        for p in self.prefills:
            for k, v in p.prefix_stats().items():
                agg[k] += v
        agg["hit_rate"] = agg["hits"] / agg["lookups"] if agg["lookups"] \
            else 0.0
        return agg

    def recent_admission_waits(self, n: int = 64) -> List[float]:
        """Tail of per-request admission waits (overlapped: scheduler
        ledger; blocking: D2D stalls) — the RatioAdjuster's
        decode-pressure signal."""
        if self.sched is not None:
            return list(self.sched.admission_waits[-n:])
        return list(self.blocking_waits[-n:])

    def transfer_stats(self) -> Dict[str, float]:
        """Per-group D2D pipeline stats: overlapped mode reports the
        scheduler's virtual-time ledger, blocking mode the synchronous
        stalls paid at the hand-off event. Both carry the group's
        MEASURED engine wall times (the same numbers the vclock
        charges), so the overlap pipeline's ready/busy arithmetic tracks
        the fused engines' real speed rather than a profiled guess.

        Prefill compile-stall telemetry rides along: the SHARED jitted
        prefill's live compile count (cluster-wide, O(num_buckets) under
        bucketing), this group's bucket hit rate (fraction of batch
        launches landing on an already-compiled shape — misses are
        compile stalls the RatioAdjuster/benchmarks can now see) and the
        pad-waste ratio (bucket-padding tokens over all tokens pushed
        through the forward)."""
        if self.sched is not None:
            out = dict(self.sched.stats())
            out["overlapped"] = 1.0
        else:
            w = self.blocking_waits
            out = {
                "overlapped": 0.0,
                "jobs_admitted": float(self.n_blocking_admits),
                "retries": 0.0, "requeues": 0.0,
                "admission_wait_mean_s": _mean(w),
                "link_busy_s": sum(w),
                "state_segments": 0.0, "state_payload_bytes": 0.0,
            }
        # medians: first samples per shape carry one-time JIT compile cost
        out["decode_step_median_s"] = _median(self.decode_step_s[-32:])
        out["prefill_batch_median_s"] = _median(self.prefill_batch_s[-32:])
        engines = [p.engine for p in self.prefills]
        batches = sum(e.prefill_batches for e in engines)
        hits = sum(e.bucket_hits for e in engines)
        comp = sum(e.compute_tokens for e in engines)
        padt = sum(e.padded_tokens for e in engines)
        out["prefill_compile_count"] = float(prefill_compile_count())
        out["prefill_batches"] = float(batches)
        out["prefill_bucket_hit_rate"] = hits / batches if batches else 0.0
        out["prefill_pad_waste"] = padt / (comp + padt) \
            if comp + padt else 0.0
        for k, v in self.absorbs.items():   # chunked-prefill elasticity
            out[k] = float(v)
        if self.scaler is not None:         # autoscale ledger (scale_*)
            out.update(self.scaler.group_ledger(self.gid))
        if self.ft is not None:    # recovery ledger (serving/faults.py)
            out.update(self.ft.ledger())
        return out

    def stats(self) -> Dict[str, float]:
        n_p, n_d = self.ratio
        pf = self.prefix_stats()
        tf = self.transfer_stats()
        return {
            "n_p": n_p, "n_d": n_d,
            "accepted": self.n_accepted,
            "rejections": self.rejections,
            "probe_rejections": self.probe_rejections,
            "flips": self.n_flips,
            "ttft_s_mean": _mean(self.ttft_s),
            "prefix_hit_rate": pf["hit_rate"],
            "reused_tokens": pf["reused_tokens"],
            "transfer_overlapped": tf["overlapped"],
            "transfer_admission_wait_s": tf["admission_wait_mean_s"],
            "transfer_requeues": tf["requeues"],
        }


class RatioAdjuster:
    """Runtime P/D ratio adjustment for one ServeGroup (§3.3, Fig. 12).

    Every `interval` adjust steps: compute the Eq.1 optimum for the
    group's current node count from `profile` (profiled in advance) or
    from the group's observed timings, and flip ONE node toward it. When
    no profile is available yet, fall back to pure queue/TTFT pressure:
    gateway backlog + busy prefills + an idle decode means the prefill
    side is the bottleneck, and vice versa. A flip fires only after two
    consecutive adjust steps agree on the direction (hysteresis: noisy
    observed timings near the optimum must not ping-pong a node).
    Under the staged shim the adjust step IS the tick; the tickless
    frontend fires adjust steps every ``adjust_period_s`` virtual
    seconds instead.

    The per-group transfer pipeline's ADMISSION-WAIT ledger
    (ServeGroup.recent_admission_waits) weighs in alongside Eq.1 and the
    queue/TTFT pressure: prefilled KV waiting on a decode slot is decode
    starvation the TTFT-side signals cannot see, so a spike (recent
    waits >= wait_spike x the earlier window) votes P->D. An
    agreeing-or-unopposed vote shifts the suggestion; a vote that
    contradicts Eq.1 cancels the step, and after a wait-driven flip the
    opposite (D->P) correction is suppressed for ``wait_cooldown``
    adjust intervals — the relieved spike would otherwise expire
    immediately and Eq.1 would revert the flip every cycle, paying two
    node drains per round trip (conflicting evidence must not
    ping-pong nodes)."""

    def __init__(self, group: ServeGroup, *, interval: int = 8,
                 min_each: int = 1,
                 profile: Optional[InstanceProfile] = None,
                 wait_spike: float = 2.0, wait_min_s: float = 1e-5,
                 wait_cooldown: int = 4):
        self.group = group
        self.interval = max(1, interval)
        self.min_each = min_each
        self.profile = profile
        self.wait_spike = wait_spike
        self.wait_min_s = wait_min_s
        self.wait_cooldown = wait_cooldown
        self.decisions: List[Tuple[int, str]] = []
        self.wait_votes: List[int] = []    # ticks the wait signal fired
        self._last_want: Optional[str] = None
        self._wait_count = 0               # admissions seen at last eval
        self._wait_flip_tick: Optional[int] = None

    def _admission_wait_signal(self) -> Optional[str]:
        """P->D when the tail of admission waits spikes over the earlier
        window: segments are landing faster than decode frees slots.
        Only FRESH samples can vote — without new admissions since the
        last adjust tick the signal expires, so one historical burst
        cannot keep voting (or keep vetoing the corrective flip) on a
        quiet group."""
        g = self.group
        count = int(g.sched.n_admitted if g.sched is not None
                    else g.n_blocking_admits)
        fresh = count - self._wait_count
        self._wait_count = count
        if fresh <= 0:
            return None
        w = g.recent_admission_waits(64)
        if len(w) < 8:
            return None
        recent, base = _mean(w[-4:]), _mean(w[:-4])
        if recent >= self.wait_spike * max(base, self.wait_min_s):
            return "P->D"
        return None

    def maybe_adjust(self, tick_no: int, backlog: int = 0) -> Optional[str]:
        """`backlog`: gateway-queued requests homed to this group."""
        if tick_no == 0 or tick_no % self.interval:
            return None
        g = self.group
        if len(self.decisions) > 512:       # windowed retention
            del self.decisions[:-256]
        if len(self.wait_votes) > 512:
            del self.wait_votes[:-256]
        if g.scale_op is not None:
            # the autoscaler has a provision/decommission in flight:
            # stand down (hysteresis too — a half-confirmed flip must
            # not fire against the post-scale capacity)
            self._last_want = None
            return None
        if g.draining_nodes():
            return None   # one flip in flight at a time
        n_p, n_d = g.ratio
        total = n_p + n_d
        if total < 2 * self.min_each + 1:
            return None   # nothing to flip without violating min_each
        wait_want = self._admission_wait_signal()
        if wait_want is not None:
            self.wait_votes.append(tick_no)
        prof = self.profile or g.observed_profile()
        if prof is not None:
            # profile leads: at the Eq.1 optimum, only the admission-wait
            # vote (decode starvation Eq.1's medians lag behind) can
            # shift the suggestion; plain pressure fall-through here
            # would oscillate
            t_p, _ = optimal_ratio(prof, total, min_each=self.min_each)
            if t_p > n_p:
                want = "D->P"
            elif t_p < n_p:
                want = "P->D"
            else:
                want = wait_want
        else:
            want = self._pressure_signal(backlog) or wait_want
        wait_driven = want is not None and want == wait_want
        if want is not None and wait_want is not None and want != wait_want:
            want = None                   # conflicting evidence: stand down
        if (want == "D->P" and self._wait_flip_tick is not None
                and tick_no - self._wait_flip_tick
                < self.wait_cooldown * self.interval):
            want = None   # let the wait-driven extra decode prove itself
        if want is None:
            self._last_want = None
            return None
        if want != self._last_want:
            self._last_want = want        # needs confirmation next tick
            return None
        self._last_want = None
        if g.request_flip("D" if want == "D->P" else "P",
                          min_each=self.min_each) is None:
            return None
        if wait_driven:
            self._wait_flip_tick = tick_no
        self.decisions.append((tick_no, want))
        return want

    def _pressure_signal(self, backlog: int) -> Optional[str]:
        g = self.group
        tt = g.ttft_s
        ttft_rising = (len(tt) >= 16
                       and _mean(tt[-8:]) > 1.5 * _mean(tt[-16:-8]))
        prefill_busy = all(p.draining or not p.idle() for p in g.prefills)
        decode_idle = any(not d.draining and not d.requests
                          for d in g.decodes)
        if (backlog > 0 or ttft_rising) and prefill_busy and decode_idle:
            return "D->P"
        decode_full = all(not d.can_admit() for d in g.decodes)
        transfer_backlog = any(p.waiting for p in g.prefills)
        prefill_free = any(not p.draining and p.idle() for p in g.prefills)
        if decode_full and transfer_backlog and prefill_free:
            return "P->D"
        return None


class ClusterFrontend:
    """Gateway over N scenario groups on one shared virtual timeline
    (§3.2, §3.5).

    topology maps scenario tag -> (n_prefill, n_decode); groups are
    named g0, g1, ... in topology order. Requests route to their
    scenario's group first (unknown scenarios fall back to the
    least-loaded group) and forward across groups only when the home
    group rejects them everywhere.

    ``tickless=True`` (default): run() / serve() drain gateway arrivals
    and every group's event frontier in global virtual-time order —
    open-loop arrival schedules submit with ``submit(req, at=t)``.
    ``tickless=False`` restores the legacy synchronous tick loop (the
    per-group staged shim); both are token-identical by test."""

    def __init__(self, cfg: ModelConfig, *,
                 topology: Optional[Dict[str, Tuple[int, int]]] = None,
                 seed: int = 0, transfer_mode: str = "block_free",
                 params=None, link: Optional[LinkModel] = None,
                 adjust_ratio: bool = False, adjust_interval: int = 8,
                 min_each: int = 1,
                 profiles: Optional[Dict[str, InstanceProfile]] = None,
                 flat_iids: bool = False,
                 prefill_kwargs: Optional[dict] = None,
                 decode_kwargs: Optional[dict] = None,
                 prefix_cache: bool = True,
                 overlap_transfer: bool = True,
                 tickless: bool = True,
                 adjust_period_s: float = 0.25,
                 spec=None, faults=None,
                 fault_kwargs: Optional[dict] = None,
                 service_model=None,
                 health_timeout_s: Optional[float] = None,
                 absorb_prefill: bool = False,
                 absorb_chunk_tokens: int = 16,
                 queue_bound: Optional[int] = None,
                 gw_backoff_base_s: float = 0.005,
                 gw_backoff_cap_s: float = 0.16,
                 gw_max_attempts: int = 8):
        topology = topology or {"default": (1, 1)}
        if faults is not None and not tickless:
            raise ValueError("fault injection (faults=) requires "
                             "tickless=True: the staged tick loop cannot "
                             "honor future-dated fault events")
        prefill_kwargs = dict(prefill_kwargs or {})
        prefill_kwargs.setdefault("prefix_cache", prefix_cache)
        if flat_iids and len(topology) > 1:
            raise ValueError("flat_iids would collide instance ids across "
                             "groups; it is only for single-group shims")
        if params is None:
            params = init_params(cfg, jax.random.PRNGKey(seed))
        self.cfg = cfg
        self.params = params
        # per-store health timeout in VIRTUAL seconds (chaos runs use
        # sub-second timeouts; the 60 s default is wall-clock scale)
        self.meta = MetaStore() if health_timeout_s is None \
            else MetaStore(health_timeout_s=health_timeout_s)
        self.xfer = KVTransferEngine(link or LinkModel(), seed=seed)
        self.transfer_mode = transfer_mode
        self.tickless = bool(tickless)
        self.groups: Dict[str, ServeGroup] = {}
        self.adjusters: Dict[str, RatioAdjuster] = {}
        profiles = profiles or {}
        for i, (scenario, (n_p, n_d)) in enumerate(topology.items()):
            g = ServeGroup(
                f"g{i}", scenario, cfg, params, self.meta, self.xfer,
                n_prefill=n_p, n_decode=n_d, transfer_mode=transfer_mode,
                overlap_transfer=overlap_transfer,
                iid_prefix="" if flat_iids else None,
                prefill_kwargs=prefill_kwargs, decode_kwargs=decode_kwargs,
                spec=self._resolve_spec(spec, scenario, seed),
                fault_plan=(faults.get(scenario)
                            if isinstance(faults, dict) else faults),
                fault_kwargs=fault_kwargs, service_model=service_model,
                absorb_prefill=absorb_prefill,
                absorb_chunk_tokens=absorb_chunk_tokens)
            g.on_capacity = self._note_capacity
            g.on_displaced = self._gw_requeue
            self.groups[scenario] = g
            if adjust_ratio:
                self.adjusters[scenario] = RatioAdjuster(
                    g, interval=adjust_interval, min_each=min_each,
                    profile=profiles.get(scenario))
        self.pending: List[ServeRequest] = []
        self.tick_no = 0
        # ------------------------------------------ shared event timeline
        self.now = 0.0                      # gateway virtual-time frontier
        self.arrivals: List[Tuple[float, int, ServeRequest]] = []
        self._aseq = itertools.count()
        self._retry = False                 # capacity freed since last try
        self.adjust_period_s = float(adjust_period_s)
        self._next_adjust = self.adjust_period_s
        self._adjust_k = 0                  # synthetic adjust-step counter
        # ---------------------------------- gateway overload control
        # capped seeded backoff for timed arrivals no group will take
        # (mirrors the fault controller's requeue policy); SLO-aware:
        # ONLY past-deadline requests shed. Deadline-less requests park
        # in ``pending`` after the attempt cap and ride capacity events.
        self.queue_bound = queue_bound
        self.gw_backoff_base_s = float(gw_backoff_base_s)
        self.gw_backoff_cap_s = float(gw_backoff_cap_s)
        self.gw_max_attempts = int(gw_max_attempts)
        self._gw_rng = random.Random((seed << 8) ^ 0x5CA1E)
        self.gw_requeues = 0
        self.gw_sheds = 0
        self.gw_backpressure = 0            # over-bound signals upstream
        # ------------------------------------------------- autoscaler
        self.autoscaler = None              # attached by AutoScaler()
        self._next_autoscale = 0.0

    def attach_autoscaler(self, scaler):
        self.autoscaler = scaler
        for g in self.groups.values():
            g.scaler = scaler
        self._next_autoscale = scaler.period_s

    def _resolve_spec(self, spec, scenario: str, seed: int):
        """Scenario-aware draft binding for ``spec=``:

        * ``None`` — plain greedy decode (default);
        * a ``SpecConfig`` — one draft for every group;
        * ``"auto"`` — per-scenario ``draft_for`` pick (a small family
          drafting for the large one, speculation depth from the
          scenario's output-length profile);
        * a dict ``{scenario: SpecConfig | "auto" | None}`` — mixed
          fleets (e.g. speculate only on the long-generation group).
        """
        if spec is None:
            return None
        if isinstance(spec, dict):
            spec = spec.get(scenario)
            if spec is None:
                return None
        if spec == "auto":
            from repro.serving.speculative import draft_for
            return draft_for(self.cfg, scenario, seed=seed)
        return spec

    @property
    def rejections(self) -> int:
        return sum(g.rejections for g in self.groups.values())

    def group_for(self, req: ServeRequest) -> ServeGroup:
        sc = getattr(req, "scenario", "default")
        g = self.groups.get(sc)
        if g is not None:
            return g
        # unknown scenario: least-loaded group (a burst must not pile
        # onto g0 while other groups idle)
        return min(self.groups.values(), key=lambda x: (x.load(), x.gid))

    # ---------------------------------------------------------- ingress
    def submit(self, req: ServeRequest, *, at: Optional[float] = None):
        """Hand a request to the gateway. ``at`` (virtual seconds)
        enqueues a timed open-loop arrival on the event timeline;
        without it the request arrives "now" (the legacy synchronous
        path stamps the home group's frontier)."""
        if at is not None:
            req.submit_t = at
            heapq.heappush(self.arrivals, (at, next(self._aseq), req))
            return
        req.submit_t = self.now if self.tickless \
            else self.group_for(req).vclock
        self.pending.append(req)

    def _try_place(self, req: ServeRequest, t: Optional[float]) -> bool:
        """On-demand forwarding within the home group, then cross-group
        fallback (§3.5); under overload, chunked-prefill absorption on an
        idle-capacity decode node is the last resort before the request
        waits at the gateway (degradation order: absorb before
        backpressure)."""
        home = self.group_for(req)
        if home.offer(req, t=t):
            return True
        for g in self.groups.values():
            if g is not home and g.offer(req, t=t):
                return True
        if t is not None:
            if home.try_absorb(req, t):
                return True
            for g in self.groups.values():
                if g is not home and g.try_absorb(req, t):
                    return True
        return False

    def _note_capacity(self, t: float):
        self._retry = True

    def _retry_pending(self):
        self._retry = False
        still: List[ServeRequest] = []
        for req in self.pending:
            if not self._try_place(req, self.now):
                still.append(req)
        self.pending = still

    # --------------------------------------- overload control (gateway)
    def queued_backlog(self, scenario: Optional[str] = None) -> int:
        """Requests waiting at the gateway (timed backoff requeues plus
        parked pending) — the autoscaler's demand-pressure signal and
        the bounded-admission-queue measure."""
        n = 0
        for _, _, r in self.arrivals:
            if r.gw_attempts > 0 and (
                    scenario is None
                    or self.group_for(r).scenario == scenario):
                n += 1
        for r in self.pending:
            if scenario is None or self.group_for(r).scenario == scenario:
                n += 1
        return n

    def _gw_shed(self, req: ServeRequest, t: float):
        req.shed = True
        req.done = True
        req.finish_t = t
        self.gw_sheds += 1

    def _gw_requeue(self, req: ServeRequest, t: float):
        """A timed arrival no group (and no absorber) would take:
        capped, seeded exponential backoff mirroring the fault
        controller's requeue policy. SLO-aware degradation: a request
        already past its deadline sheds NOW (ledgered) — only
        past-deadline requests ever shed. Past the attempt cap a
        deadline-less request parks in ``pending`` (capacity events
        retry it) instead of spinning the event heap; one with a
        deadline schedules a single final wake-up at the deadline."""
        if req.slo_deadline_s >= 0.0 and req.submit_t >= 0.0 \
                and t >= req.submit_t + req.slo_deadline_s:
            self._gw_shed(req, t)
            return
        if self.queue_bound is not None \
                and self.queued_backlog() >= self.queue_bound:
            self.gw_backpressure += 1
        a = req.gw_attempts
        req.gw_attempts = a + 1
        if a >= self.gw_max_attempts:
            if req.slo_deadline_s < 0.0 or req.submit_t < 0.0:
                self.pending.append(req)
                return
            t_next = max(req.submit_t + req.slo_deadline_s,
                         t + self.gw_backoff_cap_s)
        else:
            delay = min(self.gw_backoff_base_s * (2.0 ** a),
                        self.gw_backoff_cap_s)
            t_next = t + delay * (1.0 + 0.1 * self._gw_rng.random())
        heapq.heappush(self.arrivals, (t_next, next(self._aseq), req))
        self.gw_requeues += 1

    # ------------------------------------------------- tickless event loop
    def serve(self, *, deadline: Optional[float] = None,
              watch: Optional[Sequence[ServeRequest]] = None,
              max_events: int = 1_000_000):
        """Drain the shared timeline — gateway arrivals, per-group
        batch/transfer/decode events and link-segment landings — in
        global nondecreasing virtual time. Stops at ``deadline`` (virtual
        seconds), when ``watch`` requests are all done, or when the
        timeline is empty."""
        for g in self.groups.values():
            g._tickless = True
        try:
            if self.pending:
                self._retry_pending()
            for _ in range(max_events):
                t_arr = self.arrivals[0][0] if self.arrivals else None
                t_grp, g_next = None, None
                for g in self.groups.values():
                    tg = g.next_time()
                    if tg is not None and (t_grp is None or tg < t_grp):
                        t_grp, g_next = tg, g
                if t_arr is None and t_grp is None:
                    break
                if t_arr is not None and (t_grp is None or t_arr <= t_grp):
                    if deadline is not None and t_arr > deadline:
                        break
                    _, _, req = heapq.heappop(self.arrivals)
                    self.now = max(self.now, t_arr)
                    if not (req.done or req.shed):
                        if req.gw_attempts == 0 \
                                and self.autoscaler is not None:
                            self.autoscaler.note_arrival(
                                self.group_for(req).scenario, t_arr,
                                gen_tokens=req.max_new_tokens)
                        if not self._try_place(req, t_arr):
                            self._gw_requeue(req, t_arr)
                else:
                    if deadline is not None and t_grp > deadline:
                        break
                    self.now = max(self.now, t_grp)
                    g_next.advance(t_grp)
                    if g_next.draining_nodes():
                        g_next._complete_flips(g_next.vclock)
                if self._retry and self.pending:
                    self._retry_pending()
                if self.adjusters and self.now >= self._next_adjust:
                    self._run_adjusters()
                if self.autoscaler is not None \
                        and self.now >= self._next_autoscale:
                    self.autoscaler.step(self.now)
                    self._next_autoscale = \
                        self.now + self.autoscaler.period_s
                if watch is not None and all(r.done for r in watch):
                    break
        finally:
            for g in self.groups.values():
                g._tickless = False

    def _run_adjusters(self):
        """Periodic adjust step on the event timeline: every
        ``adjust_period_s`` virtual seconds, with a synthetic step
        counter in multiples of each adjuster's interval so the
        tick-modulo contract (and its hysteresis/cooldown arithmetic)
        carries over unchanged."""
        self._adjust_k += 1
        backlog: Dict[str, int] = {}
        for req in self.pending:
            sc = self.group_for(req).scenario
            backlog[sc] = backlog.get(sc, 0) + 1
        for sc, adj in self.adjusters.items():
            adj.maybe_adjust(self._adjust_k * adj.interval,
                             backlog.get(sc, 0))
        self._next_adjust = self.now + self.adjust_period_s

    # ----------------------------------------------- staged tick (shim)
    def tick(self):
        # 1. gateway: on-demand forwarding within the home group, then
        #    cross-group fallback (§3.5); unplaced requests wait here
        still: List[ServeRequest] = []
        for req in self.pending:
            if not self._try_place(req, None):
                still.append(req)
        self.pending = still
        # 2-4. per-group prefill / transfer / decode (+ drained flips)
        backlog: Dict[str, int] = {}
        for req in self.pending:
            sc = self.group_for(req).scenario
            backlog[sc] = backlog.get(sc, 0) + 1
        for g in self.groups.values():
            g.tick(self.tick_no)
        for sc, adj in self.adjusters.items():
            adj.maybe_adjust(self.tick_no, backlog.get(sc, 0))
        self.tick_no += 1
        self.now = max([self.now]
                       + [g.vclock for g in self.groups.values()])

    def run(self, requests: Sequence[ServeRequest], *,
            max_ticks: int = 200) -> List[ServeRequest]:
        if self.tickless:
            for r in requests:
                self.submit(r, at=self.now)
            self.serve(watch=list(requests))
            return list(requests)
        for r in requests:
            self.submit(r)
        for _ in range(max_ticks):
            self.tick()
            if all(r.done for r in requests):
                break
        return list(requests)

    def stats(self) -> Dict[str, Dict[str, float]]:
        return {sc: g.stats() for sc, g in self.groups.items()}

    def transfer_stats(self) -> Dict[str, Dict[str, float]]:
        """Per-group transfer/overlap ledgers (Fig. 10 observability)."""
        return {sc: g.transfer_stats() for sc, g in self.groups.items()}

    def gateway_stats(self) -> Dict[str, float]:
        """Overload-control ledger: backoff requeues, SLO sheds,
        backpressure signals and the live gateway backlog."""
        return {
            "gw_requeues": float(self.gw_requeues),
            "gw_sheds": float(self.gw_sheds),
            "gw_backpressure": float(self.gw_backpressure),
            "gw_backlog": float(self.queued_backlog()),
        }
