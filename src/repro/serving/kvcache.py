"""Paged KV pool (PageAttention-style, paper §2.2.3).

Storage layout: (layers, num_blocks, block_size, width) where width packs
K and V (2 * kv_dim) — flat bytes per (layer, block), which is exactly what
the block-free transfer path linearizes.

The gather (blocks -> contiguous) and scatter (contiguous -> blocks) hot
paths go through the Pallas kernels in repro.kernels (interpret mode on
CPU), with a pure-jnp fallback.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


class PoolExhausted(RuntimeError):
    pass


class PagedKVPool:
    def __init__(self, cfg: ModelConfig, *, num_blocks: int,
                 block_size: int = 16, dtype=jnp.float32,
                 use_kernels: bool = True):
        self.cfg = cfg
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.width = 2 * cfg.kv_dim                  # K ++ V
        self.layers = cfg.num_layers if not cfg.attn_free else 0
        n_attn = sum(1 for k in cfg.layer_kinds() if k == "attn")
        self.attn_layers = n_attn
        self.dtype = dtype
        self.use_kernels = use_kernels
        self.storage = jnp.zeros(
            (max(n_attn, 1), num_blocks, block_size, self.width), dtype)
        self._free: List[int] = list(range(num_blocks))
        self._owned: Dict[int, List[int]] = {}       # rid -> blocks

    # ------------------------------------------------------------- alloc
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def blocks_for_tokens(self, tokens: int) -> int:
        return max(1, math.ceil(tokens / self.block_size))

    def alloc(self, rid: int, tokens: int) -> List[int]:
        n = self.blocks_for_tokens(tokens)
        if n > len(self._free):
            raise PoolExhausted(f"need {n} blocks, have {len(self._free)}")
        blocks = [self._free.pop() for _ in range(n)]
        self._owned.setdefault(rid, []).extend(blocks)
        return blocks

    def extend(self, rid: int, extra_tokens_from: int, to_tokens: int
               ) -> List[int]:
        """Grow a request's allocation (decode appends)."""
        have = self.blocks_for_tokens(extra_tokens_from)
        need = self.blocks_for_tokens(to_tokens)
        out = []
        for _ in range(need - have):
            if not self._free:
                raise PoolExhausted("pool exhausted on extend")
            b = self._free.pop()
            self._owned.setdefault(rid, []).append(b)
            out.append(b)
        return out

    def release(self, rid: int):
        for b in self._owned.pop(rid, []):
            self._free.append(b)

    def owned(self, rid: int) -> List[int]:
        return list(self._owned.get(rid, []))

    def invariant_ok(self) -> bool:
        owned = [b for bs in self._owned.values() for b in bs]
        all_ids = sorted(owned + self._free)
        return (all_ids == list(range(self.num_blocks))
                and len(set(owned)) == len(owned))

    # ---------------------------------------------------------- data I/O
    def write_prefill(self, blocks: Sequence[int], k: jax.Array,
                      v: jax.Array):
        """k, v: (attn_layers, tokens, kv_dim) from forward_prefill."""
        L, s, kvd = k.shape
        kv = jnp.concatenate([k, v], axis=-1).astype(self.dtype)
        pad = len(blocks) * self.block_size - s
        if pad:
            kv = jnp.pad(kv, ((0, 0), (0, pad), (0, 0)))
        kv = kv.reshape(L, len(blocks), self.block_size, self.width)
        self.storage = self.storage.at[:, jnp.asarray(blocks)].set(kv)

    def append_token(self, blocks: Sequence[int], pos: int,
                     k_tok: jax.Array, v_tok: jax.Array):
        """k_tok, v_tok: (attn_layers, kv_dim); pos is the token index."""
        b = blocks[pos // self.block_size]
        off = pos % self.block_size
        kv = jnp.concatenate([k_tok, v_tok], axis=-1).astype(self.dtype)
        self.storage = self.storage.at[:, b, off, :].set(kv)

    def read_block(self, block: int) -> jax.Array:
        return self.storage[:, block]                # (layers, bs, width)

    def write_block(self, block: int, data: jax.Array):
        self.storage = self.storage.at[:, block].set(data.astype(self.dtype))

    def read_tokens(self, blocks: Sequence[int], tokens: int) -> jax.Array:
        """Dense (layers, tokens, width) view of a request's cache."""
        buf = self.gather_contiguous(blocks)
        return buf[:, :tokens]

    # ----------------------------------------------- contiguous transfer
    def gather_contiguous(self, blocks: Sequence[int]) -> jax.Array:
        """(layers, n*block_size, width) contiguous buffer (C3 sender)."""
        from repro.kernels import ops
        idx = jnp.asarray(list(blocks), jnp.int32)
        if self.use_kernels:
            return ops.kv_gather(self.storage, idx)
        g = jnp.take(self.storage, idx, axis=1)
        L, n, bs, w = g.shape
        return g.reshape(L, n * bs, w)

    def scatter_contiguous(self, buf: jax.Array, blocks: Sequence[int]):
        """RecvScatter: restore discrete blocks from bytes (C3 receiver)."""
        from repro.kernels import ops
        idx = jnp.asarray(list(blocks), jnp.int32)
        if self.use_kernels:
            self.storage = ops.kv_scatter(self.storage, buf.astype(self.dtype),
                                          idx)
        else:
            L, t, w = buf.shape
            n = len(blocks)
            self.storage = self.storage.at[:, idx].set(
                buf.reshape(L, n, self.block_size, w).astype(self.dtype))

    def block_tables(self, rids: Sequence[int], max_blocks: int
                     ) -> np.ndarray:
        """(len(rids), max_blocks) int32 table, -1 padded."""
        out = np.full((len(rids), max_blocks), -1, np.int32)
        for i, rid in enumerate(rids):
            bs = self._owned.get(rid, [])
            out[i, :len(bs)] = bs
        return out
