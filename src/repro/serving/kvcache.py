"""Paged KV pool (PageAttention-style, paper §2.2.3) with real
block-level prefix reuse (paper §2.2.1).

Storage layout: (layers, num_blocks, block_size, width) where width packs
K and V (2 * kv_dim) — flat bytes per (layer, block), which is exactly what
the block-free transfer path linearizes.

The gather (blocks -> contiguous) and scatter (contiguous -> blocks) hot
paths go through the Pallas kernels in repro.kernels (interpret mode on
CPU), with a pure-jnp fallback.

Prefix reuse (``enable_prefix_cache=True``, prefill pools only): after a
prefill, the request's full blocks are registered in a block-granular
radix trie keyed on token-id chunks. A later request walks the trie,
takes shared references (refcounted) on every fully-matched block, and
copy-on-writes the partially-matched tail block into a private copy it
may fill freely. ``release`` drops references instead of freeing shared
blocks, leaving refcount-0 prefix blocks resident and LRU-evictable;
allocation pressure evicts them (leaf-first) instead of raising
``PoolExhausted`` outright. A block a live request holds is never
evicted, freed, or overwritten. The placement-accounting twin of this
mechanism (simulator side) lives in ``repro.core.prefix_cache``.

Recurrent-state snapshots (SSM/hybrid families): alongside the KV
blocks, the trie stores boundary snapshots — per-layer (conv tails,
SSD state) trees keyed by the cached block whose END is the snapshot
boundary. A snapshot lives and dies with its block: it is attached at
``insert_prefix`` (boundary -> state, supplied by the engine's
``snap_stride`` emission), dropped in ``_evict_one`` the moment the
block is evicted (lockstep eviction — a snapshot never outlives or
orphans its blocks; leaf-first eviction keeps every snapshot's chain
rooted), and never copied on COW (a COW tail is a *partial* block, so
its end is never a snapshot boundary). ``require_state`` acquires round
the hit DOWN to the nearest boundary that still holds a snapshot —
SSM engines cannot restore from a KV-only match.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


class PoolExhausted(RuntimeError):
    pass


class _PrefixNode:
    """One cached block in the radix trie. ``key`` is the exact token-id
    chunk the block holds (len < block_size == partial tail leaf)."""

    __slots__ = ("key", "block", "parent", "children", "last_use")

    def __init__(self, key: Tuple[int, ...], block: int,
                 parent: Optional["_PrefixNode"]):
        self.key = key
        self.block = block
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_PrefixNode"] = {}
        self.last_use = 0


class PagedKVPool:
    def __init__(self, cfg: ModelConfig, *, num_blocks: int,
                 block_size: int = 16, dtype=jnp.float32,
                 use_kernels: bool = True,
                 enable_prefix_cache: bool = False):
        self.cfg = cfg
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.width = 2 * cfg.kv_dim                  # K ++ V
        self.layers = cfg.num_layers if not cfg.attn_free else 0
        n_attn = sum(1 for k in cfg.layer_kinds() if k == "attn")
        self.attn_layers = n_attn
        self.dtype = dtype
        self.use_kernels = use_kernels
        self.storage = jnp.zeros(
            (max(n_attn, 1), num_blocks, block_size, self.width), dtype)
        self._free: List[int] = list(range(num_blocks))
        self._owned: Dict[int, List[int]] = {}       # rid -> blocks
        # ---- prefix index state (enable_prefix_cache only) ----
        # attn-free (pure SSM) stacks cache too: their zero-width KV
        # blocks are trie key-holders for the boundary snapshots
        self.enable_prefix_cache = bool(enable_prefix_cache)
        self._roots: Dict[Optional[str], _PrefixNode] = {}
        self._cached: Dict[int, _PrefixNode] = {}    # block -> trie node
        self._ref: Dict[int, int] = {}               # cached block -> holders
        # recurrent-state snapshots: cached block -> per-(blk,sub) state
        # tree at the boundary ENDING at that block (lockstep-evicted)
        self._snaps: Dict[int, dict] = {}
        self._clock = 0
        # observability
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.evictions = 0
        self.cow_copies = 0
        self.storage_writes = 0      # engine-issued storage swaps
        self.snap_hits = 0           # acquires served with a snapshot
        self.snap_misses = 0         # KV match degraded: boundary had none
        self.snap_stores = 0         # snapshots attached to the trie
        self.snap_bytes = 0          # resident snapshot bytes

    def set_storage(self, storage: jax.Array):
        """Adopt a new storage buffer (the decode engines route their
        per-step pool updates through here: the eager loop swaps once
        per attention layer per step, the fused jitted step exactly once
        per step with the old buffer donated — the aliasing test pins
        that contract on ``storage_writes``)."""
        self.storage = storage
        self.storage_writes += 1

    # ------------------------------------------------------------- alloc
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def cached_blocks(self) -> int:
        return len(self._cached)

    def blocks_for_tokens(self, tokens: int) -> int:
        return max(1, math.ceil(tokens / self.block_size))

    def _take_free(self, n: int) -> List[int]:
        """Pop n free blocks, evicting LRU refcount-0 prefix blocks under
        pressure instead of failing outright."""
        while len(self._free) < n and self._evict_one():
            pass
        if n > len(self._free):
            raise PoolExhausted(f"need {n} blocks, have {len(self._free)} "
                                f"free and nothing evictable")
        return [self._free.pop() for _ in range(n)]

    def alloc(self, rid: int, tokens: int) -> List[int]:
        blocks = self._take_free(self.blocks_for_tokens(tokens))
        self._owned.setdefault(rid, []).extend(blocks)
        return blocks

    def alloc_to(self, rid: int, tokens: int) -> List[int]:
        """Grow rid's allocation so it covers `tokens` total tokens
        (suffix blocks after a prefix hit)."""
        have = len(self._owned.get(rid, []))
        need = max(0, self.blocks_for_tokens(tokens) - have)
        blocks = self._take_free(need)
        self._owned.setdefault(rid, []).extend(blocks)
        return blocks

    def extend(self, rid: int, extra_tokens_from: int, to_tokens: int
               ) -> List[int]:
        """Grow a request's allocation (decode appends)."""
        have = self.blocks_for_tokens(extra_tokens_from)
        need = self.blocks_for_tokens(to_tokens)
        out = self._take_free(max(0, need - have))
        self._owned.setdefault(rid, []).extend(out)
        return out

    def release(self, rid: int):
        for b in self._owned.pop(rid, []):
            if b in self._cached:
                # shared prefix block: drop the reference, keep it cached
                # (refcount 0 == LRU-evictable, never freed while held)
                self._ref[b] = max(0, self._ref.get(b, 0) - 1)
            else:
                self._free.append(b)

    def owned(self, rid: int) -> List[int]:
        return list(self._owned.get(rid, []))

    def invariant_ok(self) -> bool:
        owned_all = [b for bs in self._owned.values() for b in bs]
        cached = set(self._cached)
        private = [b for b in owned_all if b not in cached]
        ok = len(private) == len(set(private))       # unique private owner
        counts: Dict[int, int] = {}
        for b in owned_all:
            if b in cached:
                counts[b] = counts.get(b, 0) + 1
        ok &= all(self._ref.get(b, 0) == counts.get(b, 0) for b in cached)
        ok &= len(self._free) == len(set(self._free))
        ok &= not (set(self._free) & (set(private) | cached))
        ok &= sorted(set(self._free) | set(private) | cached) \
            == list(range(self.num_blocks))
        # a snapshot never outlives its block: every snapshot key must
        # be a live cached block (lockstep eviction)
        ok &= set(self._snaps) <= cached
        return bool(ok)

    # ----------------------------------------------------- prefix index
    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def _match(self, tokens: Sequence[int], namespace: Optional[str]
               ) -> Tuple[List[_PrefixNode], Optional[Tuple[_PrefixNode,
                                                            int]]]:
        """Walk the trie: fully-matched whole blocks, plus the best
        partial tail candidate (node, common-prefix token count)."""
        root = self._roots.get(namespace)
        if root is None:
            return [], None
        toks = tuple(int(t) for t in tokens)
        bs = self.block_size
        chain: List[_PrefixNode] = []
        node = root
        i = 0
        while True:
            rest = toks[i:]
            if not rest:
                return chain, None
            child = node.children.get(rest[:bs])
            if child is not None and len(rest) >= bs:
                chain.append(child)
                node = child
                i += bs
                continue
            # tail: the child sharing the longest common token prefix
            # with the remaining tokens (full or partial block — either
            # way the overlap is COW-copied, never referenced in place)
            best, best_l = None, 0
            for key, ch in node.children.items():
                l = 0
                for a, b in zip(key, rest):
                    if a != b:
                        break
                    l += 1
                if l > best_l:
                    best, best_l = ch, l
            return chain, ((best, best_l) if best is not None else None)

    def _snap_floor(self, full: List[_PrefixNode], target: int,
                    align: int) -> int:
        """Round an aligned match DOWN to the nearest boundary holding a
        recurrent-state snapshot (require_state acquires). ``align``
        must cover whole blocks in this mode, so every candidate
        boundary ends exactly at a full-block node."""
        bs = self.block_size
        assert align % bs == 0, (align, bs)
        target = min(target, len(full) * bs)
        target -= target % align
        while target > 0 and \
                full[target // bs - 1].block not in self._snaps:
            target -= align
        return target

    def peek_prefix(self, tokens: Sequence[int],
                    namespace: Optional[str] = None,
                    align: int = 1, require_state: bool = False) -> int:
        """Read-only match length in tokens (for routing affinity);
        does not touch refcounts or recency. ``align`` rounds the
        reported hit DOWN to a multiple (capacity-MoE engines require
        window-aligned prefixes — see PrefillEngine.prefix_align);
        ``require_state`` further rounds down to the nearest snapshot
        boundary (SSM engines cannot restore from a KV-only match)."""
        if not self.enable_prefix_cache or len(tokens) < 2:
            return 0
        full, tail = self._match(tokens, namespace)
        got = len(full) * self.block_size + (tail[1] if tail else 0)
        got = min(got, len(tokens) - 1)
        got -= got % max(1, align)
        if require_state:
            got = self._snap_floor(full, got, align)
        return got

    def acquire_prefix(self, rid: int, tokens: Sequence[int],
                       namespace: Optional[str] = None,
                       align: int = 1, require_state: bool = False) -> int:
        """Prefix lookup at admission: matched whole blocks become shared
        (refcounted) leading blocks of rid's allocation; a partial tail
        match is copy-on-written into a private block. Returns the cached
        token count (always < len(tokens): the last prompt token is
        recomputed so prefill still yields first-token logits). With
        ``align`` > 1 the hit is rounded DOWN to a multiple — a
        whole-block match past the boundary degrades into a COW tail (or
        is dropped), so engines whose suffix math needs aligned reuse
        boundaries (window-local capacity MoE) stay exact.

        ``require_state`` (SSM/hybrid engines): the hit must land on a
        boundary whose block holds a recurrent-state snapshot — a match
        cut anywhere else (including any would-be COW tail) degrades to
        the nearest snapshot boundary below, or to a clean miss. The
        caller reads the snapshot back with ``snapshot_for``."""
        if not self.enable_prefix_cache or len(tokens) < 2:
            return 0
        self.lookups += 1
        full, tail = self._match(tokens, namespace)
        bs = self.block_size
        raw = len(full) * bs + (tail[1] if tail else 0)
        target = min(raw, len(tokens) - 1)
        target -= target % max(1, align)
        if require_state:
            want = target
            target = self._snap_floor(full, target, align)
            if want > 0 and target < want:
                self.snap_misses += 1   # KV matched past the boundary
            if target > 0:
                self.snap_hits += 1
        n_full = min(len(full), target // bs)
        rem = target - n_full * bs
        tail_node = None
        if rem > 0:
            # the boundary cuts into a matched block: COW its overlap
            tail_node = full[n_full] if n_full < len(full) else tail[0]
        if target <= 0:
            return 0
        blocks: List[int] = []
        for nd in full[:n_full]:
            self._ref[nd.block] = self._ref.get(nd.block, 0) + 1
            blocks.append(nd.block)
        if tail_node is not None:
            # pin the source so eviction pressure from _take_free cannot
            # reclaim it mid-copy
            self._ref[tail_node.block] = self._ref.get(tail_node.block,
                                                       0) + 1
            try:
                dst = self._take_free(1)[0]
            except PoolExhausted:
                # no room for the COW tail: degrade to the whole-block
                # hit (or a clean miss), rolling back refs not yet
                # recorded in _owned — they would leak otherwise
                dst = None
            finally:
                self._ref[tail_node.block] -= 1
            if dst is None:
                tail_node, rem = None, 0
                # the degraded whole-block hit must still respect the
                # alignment contract: keep only the largest block count
                # whose token span is an align multiple, rolling back
                # the refs on dropped blocks (run_suffix asserts
                # plen % align == 0 at admission)
                while n_full and (n_full * bs) % max(1, align):
                    n_full -= 1
                    self._ref[full[n_full].block] -= 1
                    blocks.pop()
                if not blocks:
                    return 0
            else:
                self.storage = self.storage.at[:, dst].set(
                    self.storage[:, tail_node.block])
                self.cow_copies += 1
                blocks.append(dst)
        cached = n_full * bs + rem
        self._owned.setdefault(rid, []).extend(blocks)
        self.hits += 1
        self.hit_tokens += cached
        self._touch(full[n_full - 1] if n_full else tail_node)
        return cached

    @staticmethod
    def _snap_nbytes(state: dict) -> int:
        return sum(int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize
                   for a in jax.tree.leaves(state))

    def insert_prefix(self, rid: int, tokens: Sequence[int],
                      namespace: Optional[str] = None,
                      states: Optional[Dict[int, dict]] = None):
        """Register rid's prefilled blocks in the trie so later requests
        can share them. Blocks already shared (matched at acquire time)
        are only recency-touched; private blocks become cached with the
        owning request as their first reference.

        ``states`` maps ABSOLUTE token boundaries -> recurrent-state
        snapshot trees (the engine's ``snap_stride`` emission): each is
        attached to the cached block ending at its boundary, so it is
        refcounted/evicted in lockstep with that block. Pre-existing
        nodes missing a snapshot pick one up too (a warm run emits
        snapshots for the NEW suffix boundaries only, but a cold rerun
        of a longer prompt may backfill earlier boundaries)."""
        if not self.enable_prefix_cache:
            return
        blocks = self._owned.get(rid, [])
        root = self._roots.setdefault(namespace, _PrefixNode((), -1, None))
        toks = tuple(int(t) for t in tokens)
        bs = self.block_size
        node = root
        self._clock += 1
        for i, b in enumerate(blocks):
            chunk = toks[i * bs:(i + 1) * bs]
            if not chunk:
                break
            child = node.children.get(chunk)
            if child is None:
                if b in self._cached:
                    break   # defensive: a block caches under one node only
                child = _PrefixNode(chunk, b, node)
                node.children[chunk] = child
                self._cached[b] = child
                self._ref[b] = self._ref.get(b, 0) + 1   # rid holds it
            child.last_use = self._clock
            if len(chunk) == bs and states \
                    and (i + 1) * bs in states \
                    and child.block not in self._snaps:
                st = states[(i + 1) * bs]
                self._snaps[child.block] = st
                self.snap_stores += 1
                self.snap_bytes += self._snap_nbytes(st)
            if len(chunk) < bs:
                break       # partial tail is a leaf
            node = child

    def snapshot_for(self, rid: int, cached: int) -> dict:
        """The recurrent-state snapshot at rid's acquired boundary
        (``cached`` tokens, as returned by a require_state acquire)."""
        bs = self.block_size
        assert cached > 0 and cached % bs == 0, cached
        return self._snaps[self._owned[rid][cached // bs - 1]]

    def _touch(self, node: Optional[_PrefixNode]):
        self._clock += 1
        while node is not None and node.key:
            node.last_use = self._clock
            node = node.parent

    def _evict_one(self) -> bool:
        """Free the LRU evictable trie leaf (refcount 0, no children).
        Leaf-first ordering keeps every cached chain rooted."""
        best: Optional[_PrefixNode] = None
        for b, nd in self._cached.items():
            if self._ref.get(b, 0) == 0 and not nd.children:
                if best is None or nd.last_use < best.last_use:
                    best = nd
        if best is None:
            return False
        del self._cached[best.block]
        self._ref.pop(best.block, None)
        # lockstep: the boundary snapshot dies with its block
        snap = self._snaps.pop(best.block, None)
        if snap is not None:
            self.snap_bytes -= self._snap_nbytes(snap)
        if best.parent is not None:
            best.parent.children.pop(best.key, None)
        self._free.append(best.block)
        self.evictions += 1
        return True

    # ---------------------------------------------------------- data I/O
    def write_prefill(self, blocks: Sequence[int], k: jax.Array,
                      v: jax.Array):
        """k, v: (attn_layers, tokens, kv_dim) from forward_prefill."""
        L, s, kvd = k.shape
        kv = jnp.concatenate([k, v], axis=-1).astype(self.dtype)
        pad = len(blocks) * self.block_size - s
        if pad:
            kv = jnp.pad(kv, ((0, 0), (0, pad), (0, 0)))
        kv = kv.reshape(L, len(blocks), self.block_size, self.width)
        self.storage = self.storage.at[:, jnp.asarray(blocks)].set(kv)

    def write_tokens(self, blocks: Sequence[int], start: int,
                     k: jax.Array, v: jax.Array):
        """Write k/v (attn_layers, n, kv_dim) at token offset `start` of a
        request's block list — the suffix write after a prefix hit. Only
        blocks at/after `start` are touched, so shared prefix blocks are
        never overwritten."""
        L, n, kvd = k.shape
        kv = jnp.concatenate([k, v], axis=-1).astype(self.dtype)
        bs = self.block_size
        toks = np.arange(start, start + n)
        blk = jnp.asarray(np.asarray(blocks)[toks // bs])
        off = jnp.asarray(toks % bs)
        # single scatter: one buffer update regardless of span count
        self.storage = self.storage.at[:, blk, off].set(kv)

    def append_token(self, blocks: Sequence[int], pos: int,
                     k_tok: jax.Array, v_tok: jax.Array):
        """k_tok, v_tok: (attn_layers, kv_dim); pos is the token index."""
        b = blocks[pos // self.block_size]
        off = pos % self.block_size
        kv = jnp.concatenate([k_tok, v_tok], axis=-1).astype(self.dtype)
        self.storage = self.storage.at[:, b, off, :].set(kv)

    def read_block(self, block: int) -> jax.Array:
        return self.storage[:, block]                # (layers, bs, width)

    def write_block(self, block: int, data: jax.Array):
        self.storage = self.storage.at[:, block].set(data.astype(self.dtype))

    def read_tokens(self, blocks: Sequence[int], tokens: int) -> jax.Array:
        """Dense (layers, tokens, width) view of a request's cache."""
        buf = self.gather_contiguous(blocks)
        return buf[:, :tokens]

    # ----------------------------------------------- contiguous transfer
    def layer_nbytes(self, blocks: int) -> int:
        """Wire bytes of ONE layer's stripe of a linearized n-block
        buffer (Fig. 10 offset/length arithmetic works on these)."""
        return blocks * self.block_size * self.width \
            * jnp.dtype(self.dtype).itemsize

    def gather_layer(self, blocks: Sequence[int], layer: int) -> jax.Array:
        """(n*block_size, width) contiguous view of ONE layer's stripe —
        the per-layer-triggered sender side (paper Fig. 10)."""
        from repro.kernels import ops
        idx = jnp.asarray(list(blocks), jnp.int32)
        if self.use_kernels:
            return ops.kv_gather_layer(self.storage, idx, layer)
        g = jnp.take(self.storage[layer], idx, axis=0)
        n, bs, w = g.shape
        return g.reshape(n * bs, w)

    def scatter_layer(self, buf: jax.Array, blocks: Sequence[int],
                      layer: int):
        """RecvScatter of ONE layer's stripe into discrete blocks — the
        per-layer-triggered receiver side."""
        from repro.kernels import ops
        idx = jnp.asarray(list(blocks), jnp.int32)
        if self.use_kernels:
            self.storage = ops.kv_scatter_layer(
                self.storage, buf.astype(self.dtype), idx, layer)
        else:
            t, w = buf.shape
            n = len(blocks)
            self.storage = self.storage.at[layer, idx].set(
                buf.reshape(n, self.block_size, w).astype(self.dtype))

    def gather_contiguous(self, blocks: Sequence[int]) -> jax.Array:
        """(layers, n*block_size, width) contiguous buffer (C3 sender)."""
        from repro.kernels import ops
        idx = jnp.asarray(list(blocks), jnp.int32)
        if self.use_kernels:
            return ops.kv_gather(self.storage, idx)
        g = jnp.take(self.storage, idx, axis=1)
        L, n, bs, w = g.shape
        return g.reshape(L, n * bs, w)

    def scatter_contiguous(self, buf: jax.Array, blocks: Sequence[int]):
        """RecvScatter: restore discrete blocks from bytes (C3 receiver)."""
        from repro.kernels import ops
        idx = jnp.asarray(list(blocks), jnp.int32)
        if self.use_kernels:
            self.storage = ops.kv_scatter(self.storage, buf.astype(self.dtype),
                                          idx)
        else:
            L, t, w = buf.shape
            n = len(blocks)
            self.storage = self.storage.at[:, idx].set(
                buf.reshape(L, n, self.block_size, w).astype(self.dtype))

    def block_tables(self, rids: Sequence[int], max_blocks: int
                     ) -> np.ndarray:
        """(len(rids), max_blocks) int32 table, -1 padded."""
        out = np.full((len(rids), max_blocks), -1, np.int32)
        for i, rid in enumerate(rids):
            bs = self._owned.get(rid, [])
            out[i, :len(bs)] = bs
        return out
