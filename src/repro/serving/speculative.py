"""Disaggregated speculative decoding (paper §6.1, Discussion/Extension).

A small draft model proposes K tokens autoregressively; the target model
verifies them in ONE batched forward (scoring positions pos..pos+K), and
the longest matching prefix is accepted (greedy speculative decoding is
lossless: output is token-identical to target-only decoding).

Deployment follows the paper: the draft model is disaggregated WITH the
large model — its prefill runs in the prefill instance, its decode state
lives in the decode instance — so both models' caches ride the same
block-free transfer. Here both sides run in-process with lockstep caches.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.caches import zeros_cache
from repro.models.config import ModelConfig
from repro.models.modeling import (forward_decode, forward_prefill,
                                   forward_seq, lm_logits)

Tree = Dict[str, Any]


def _pad_cache(cache: Tree, new_s: int) -> Tree:
    def f(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("k", "v") and x.ndim == 4:
            return jnp.pad(x, ((0, 0), (0, 0), (0, new_s - x.shape[2]),
                               (0, 0)))
        return x
    return {"layers": jax.tree_util.tree_map_with_path(f, cache["layers"]),
            "pos": cache["pos"]}


@dataclass
class SpecStats:
    proposed: int = 0
    accepted: int = 0
    target_steps: int = 0

    @property
    def acceptance(self) -> float:
        return self.accepted / self.proposed if self.proposed else 0.0


class SpeculativeDecoder:
    """Greedy speculative decoding for one sequence (b=1)."""

    def __init__(self, target_cfg: ModelConfig, target_params: Tree,
                 draft_cfg: ModelConfig, draft_params: Tree, *, k: int = 4):
        assert not target_cfg.is_encoder_decoder
        self.tc, self.tp = target_cfg, target_params
        self.dc, self.dp = draft_cfg, draft_params
        self.k = k
        self.stats = SpecStats()

    # ----------------------------------------------------------- helpers
    def _target_logits_at(self, tokens: List[int]) -> jax.Array:
        """Target logits for every position of `tokens` (teacher-forced)."""
        batch = {"tokens": jnp.asarray([tokens], jnp.int32)}
        h, _, _ = forward_seq(self.tc, self.tp, batch, collect_cache=False,
                              remat=False)
        return lm_logits(self.tc, self.tp, h)[0]       # (len, vocab)

    # ------------------------------------------------------------ decode
    def generate(self, prompt: List[int], max_new_tokens: int) -> List[int]:
        """Returns generated tokens (token-identical to target greedy)."""
        out: List[int] = []
        # draft keeps an incremental cache; the target re-verifies with a
        # teacher-forced forward (prefill-style verification — in the
        # disaggregated layout this runs on the prefill-side batch engine)
        horizon = len(prompt) + max_new_tokens + self.k + 2
        d_first, d_cache = forward_prefill(
            self.dc, self.dp, {"tokens": jnp.asarray([prompt], jnp.int32)})
        d_cache = _pad_cache(d_cache, horizon)
        t_logits = self._target_logits_at(prompt)
        cur = int(jnp.argmax(t_logits[-1]))            # first target token
        out.append(cur)
        self.stats.target_steps += 1
        d_tok = jnp.asarray([int(d_first[0])], jnp.int32)

        while len(out) < max_new_tokens:
            # 1. draft proposes k tokens from the current context
            proposal: List[int] = []
            d_tok = jnp.asarray([cur], jnp.int32)
            d_snapshot = d_cache
            for _ in range(self.k):
                d_tok, d_cache = forward_decode(self.dc, self.dp, d_cache,
                                                d_tok)
                proposal.append(int(d_tok[0]))
            self.stats.proposed += len(proposal)
            # 2. target verifies all k in one teacher-forced pass
            ctx = prompt + out + proposal
            logits = self._target_logits_at(ctx)
            self.stats.target_steps += 1
            base = len(prompt) + len(out) - 1
            accepted = 0
            nxt = None
            for i, tok in enumerate(proposal):
                want = int(jnp.argmax(logits[base + i]))
                if want == tok:
                    accepted += 1
                else:
                    nxt = want
                    break
            self.stats.accepted += accepted
            out.extend(proposal[:accepted])
            if len(out) >= max_new_tokens:
                break
            if nxt is None:
                # all accepted: the target's own next token is free
                nxt = int(jnp.argmax(logits[base + len(proposal)]))
            out.append(nxt)
            cur = nxt
            # 3. roll the draft cache back to the accepted point and
            #    replay the accepted suffix (keeps caches in lockstep)
            d_cache = _pad_cache(
                self._draft_cache_upto(prompt + out[:-1]), horizon)
        return out[:max_new_tokens]

    def _draft_cache_upto(self, tokens: List[int]) -> Tree:
        _, cache = forward_prefill(
            self.dc, self.dp, {"tokens": jnp.asarray([tokens], jnp.int32)})
        return cache
