"""Disaggregated speculative decoding (paper §6.1, Discussion/Extension).

A small draft model proposes K tokens autoregressively; the target model
verifies them in ONE teacher-forced sweep over the k+1 new positions,
and the longest matching prefix is accepted (greedy speculative decoding
is lossless: output is token-identical to target-only decoding).

Deployment follows the paper: the draft model is disaggregated WITH the
large model — its prefill runs in the prefill instance, its decode state
lives in the decode instance — so both models' caches ride the same
block-free transfer. ``SpeculativeDecoder`` below is the b=1 REFERENCE
ORACLE: lockstep caches, one sequence, every invariant explicit. The
production path is the fused multi-slot program
(``models.modeling.forward_spec_decode_step`` driven by
``DecodeEngine(spec=...)``), which is parity-tested against both this
oracle and the plain fused greedy step (tests/test_spec_fused.py).

Both caches are incremental:

  * the draft keeps a decode cache; each round snapshots it before
    proposing, and afterwards rolls BACK to the snapshot and replays
    only the accepted tokens through ``forward_decode`` (<= k+1 steps —
    the recurrent-safe rollback; a KV truncation would lose SSM state);
  * the target keeps a decode cache too: verification teacher-forces
    exactly the k+1 new positions through it, and the per-position
    caches captured during that sweep double as the rollback points.

Per round that is O(k) model steps — the seed-era oracle instead
re-prefilled the full prefix on both sides (O(n^2) over a generation).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.modeling import forward_decode, forward_prefill

Tree = Dict[str, Any]


def _pad_cache(cache: Tree, new_s: int) -> Tree:
    def f(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("k", "v") and x.ndim == 4:
            return jnp.pad(x, ((0, 0), (0, 0), (0, new_s - x.shape[2]),
                               (0, 0)))
        return x
    return {"layers": jax.tree_util.tree_map_with_path(f, cache["layers"]),
            "pos": cache["pos"]}


@dataclass
class SpecConfig:
    """Draft-model binding for speculative decode: which small model
    proposes, and how deep it speculates per target verification."""
    draft_cfg: ModelConfig
    draft_params: Tree
    k: int = 4


# Scenario tag -> speculation depth for the auto-picked draft: grouping
# by scenario keeps output-length statistics similar inside a group
# (§3.2), so the depth is a per-group constant — long-generation
# scenarios amortize deeper speculation, short-answer ones do not.
SCENARIO_SPEC_K = {"default": 4, "chat": 4, "qa": 3, "summarize": 2,
                   "write": 6}


def draft_for(cfg: ModelConfig, scenario: str = "default", *,
              seed: int = 0, max_blocks: int = 2) -> SpecConfig:
    """Scenario-aware draft choice (paper §6.1 co-located deployment):
    a SMALL family drafting for a large one — same vocabulary (greedy
    acceptance compares token ids), a fraction of the depth (whole
    layer blocks, so hybrid periods stay intact), freshly initialized
    params. Real deployments substitute a distilled checkpoint; the
    serving mechanics (and the losslessness guarantee) are independent
    of draft quality."""
    from repro.models.params import block_period, init_params, num_blocks
    per = block_period(cfg)
    n_blk = max(1, min(max_blocks, num_blocks(cfg) // 4))
    d_cfg = cfg.replace(num_layers=n_blk * per,
                        name=f"{cfg.name}-draft{n_blk * per}")
    d_params = init_params(d_cfg, jax.random.PRNGKey(seed))
    return SpecConfig(d_cfg, d_params,
                      k=SCENARIO_SPEC_K.get(scenario, SCENARIO_SPEC_K["default"]))


@dataclass
class SpecStats:
    proposed: int = 0        # draft tokens proposed
    accepted: int = 0        # draft tokens accepted by the target
    emitted: int = 0         # tokens actually emitted (corrections and
    #                          the all-accepted bonus token included)
    target_steps: int = 0    # target verification sweeps (+ prefill)
    draft_replay_tokens: int = 0  # rollback replays through the draft

    @property
    def acceptance(self) -> float:
        return self.accepted / self.proposed if self.proposed else 0.0

    @property
    def tokens_per_step(self) -> float:
        """EXACT emitted tokens per target sweep — the speculation
        speedup. Derived from ``emitted`` (not accepted+proposed): the
        free bonus token of an all-accepted round and the correction
        token of a rejection both count, truncation at max_new_tokens
        is subtracted back out."""
        return self.emitted / self.target_steps if self.target_steps \
            else 0.0


class SpeculativeDecoder:
    """Greedy speculative decoding for one sequence (b=1 oracle)."""

    def __init__(self, target_cfg: ModelConfig, target_params: Tree,
                 draft_cfg: ModelConfig, draft_params: Tree, *, k: int = 4):
        assert not target_cfg.is_encoder_decoder
        self.tc, self.tp = target_cfg, target_params
        self.dc, self.dp = draft_cfg, draft_params
        self.k = k
        self.stats = SpecStats()

    # ------------------------------------------------------------ decode
    def generate(self, prompt: List[int], max_new_tokens: int) -> List[int]:
        """Returns generated tokens (token-identical to target greedy)."""
        out: List[int] = []
        horizon = len(prompt) + max_new_tokens + self.k + 2
        batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
        t_first, t_cache = forward_prefill(self.tc, self.tp, batch)
        t_cache = _pad_cache(t_cache, horizon)
        _, d_cache = forward_prefill(self.dc, self.dp, batch)
        d_cache = _pad_cache(d_cache, horizon)
        cur = int(t_first[0])                          # first target token
        out.append(cur)
        self.stats.target_steps += 1
        self.stats.emitted += 1
        # loop invariant at the top of each round: both caches have
        # consumed exactly prompt + out[:-1] (the last emitted token is
        # in flight — the next round feeds it to both models first)
        while len(out) < max_new_tokens:
            # 1. draft proposes k tokens from the current context
            proposal: List[int] = []
            d_tok = jnp.asarray([cur], jnp.int32)
            d_snapshot = d_cache                       # rollback point
            for _ in range(self.k):
                d_tok, d_cache = forward_decode(self.dc, self.dp, d_cache,
                                                d_tok)
                proposal.append(int(d_tok[0]))
            self.stats.proposed += len(proposal)
            # 2. target verifies incrementally: teacher-force ONLY the
            #    k+1 new positions ([cur] + proposal) through its cache.
            #    g[i] is the target's greedy token after consuming
            #    position i; the caches captured along the sweep are the
            #    per-position rollback points (no recompute).
            g: List[int] = []
            t_steps: List[Tree] = []
            for tok in [cur] + proposal:
                gt, t_cache = forward_decode(
                    self.tc, self.tp, t_cache,
                    jnp.asarray([tok], jnp.int32))
                g.append(int(gt[0]))
                t_steps.append(t_cache)
            self.stats.target_steps += 1
            accepted = 0
            while accepted < self.k and proposal[accepted] == g[accepted]:
                accepted += 1
            self.stats.accepted += accepted
            # accepted proposals equal the target's own greedy tokens,
            # so the emission is always g[:accepted+1] — the last entry
            # is the correction on a rejection, the free bonus token
            # when all k were accepted
            emit = g[:accepted + 1]
            out.extend(emit)
            self.stats.emitted += len(emit)
            prev, cur = cur, emit[-1]
            # 3. restore the invariant. Target: the verify sweep already
            #    produced the cache at every depth — pick the one that
            #    consumed [cur] + proposal[:accepted]. Draft: roll back
            #    to the snapshot and REPLAY only the accepted tokens
            #    (recurrent-safe; an attention-only rollback could
            #    truncate, an SSM draft cannot).
            t_cache = t_steps[accepted]
            d_cache = d_snapshot
            replay = [prev] + proposal[:accepted]
            for tok in replay:
                _, d_cache = forward_decode(self.dc, self.dp, d_cache,
                                            jnp.asarray([tok], jnp.int32))
            self.stats.draft_replay_tokens += len(replay)
        overshoot = len(out) - max_new_tokens
        if overshoot > 0:
            self.stats.emitted -= overshoot            # keep stats exact
        return out[:max_new_tokens]
