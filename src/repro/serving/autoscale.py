"""SLO-goodput autoscaler over a shared heterogeneous node pool
(paper §3.2/§3.4 cluster elasticity on the REAL serving path).

The RatioAdjuster (serving/frontend.py) rebalances P/D *inside* a fixed
group; this module grows and shrinks the groups themselves against one
shared pool of heterogeneous node classes (core.profiles.NodeClass:
prefill-heavy / decode-heavy / balanced, realized as virtual
service-time multipliers — token streams are class-invariant).

Control law (DistServe-style): per scenario, a ``GoodputModel``
(core.mlops) built from the group's own measured ``transfer_stats()``
medians converts the observed arrival rate + gateway backlog into
required prefill/decode capacity under the scenario's TTFT/TPOT SLOs.
The bottleneck side scales UP when demand overruns the SLO-feasible
capacity; a side scales DOWN when demand would still fit comfortably
without its least-loaded node (pool-leased nodes drain first, so
borrowed capacity returns to the shared pool before the base topology
shrinks).

Every transition is an event on the PR-7 tickless heap:

  * scale-up  — lease a class from the pool (role-biased pick), pay the
    ``substitute_ready_delay`` provisioning timeline, then a ``scale``
    event lands the node in the group (one stateless container: connect
    + model load + health — the same Fig. 13 arithmetic the fault
    controller charges for substitutes);
  * scale-down — mark the victim ``draining + decommissioning`` (no new
    traffic; the flip machinery skips it) and poll drain completion via
    re-check ``scale`` events; decommission releases the lease (or
    ADOPTS a base-topology node into the pool).

One scale op is in flight per group at a time, and the RatioAdjuster
stands down while it is (``ServeGroup.scale_op``). Chaos composition
(PR-9): a crashed draining node is never released to the pool — the
lease survives until its substitute reboots and actually drains, so a
dead node is never double-counted as capacity; all decisions read only
event-clock state, keeping same-seed runs bit-identical.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.mlops import GoodputModel, SLOSpec, substitute_ready_delay
from repro.core.profiles import NODE_CLASSES, NodeClass


def _mean(xs) -> float:
    xs = list(xs)
    return sum(xs) / len(xs) if xs else 0.0


class NodePool:
    """Shared inventory of heterogeneous spare nodes.

    ``lease(role, iid)`` hands out one node, preferring the class biased
    toward ``role``, then unbiased, then off-bias classes — deterministic
    order. A lease is keyed by the instance id it provisions;
    ``release(iid)`` returns the SAME class to the free inventory and is
    idempotent (a second release, or a release of an unknown iid, is a
    no-op returning False) — the guard that keeps a crashed node from
    being double-counted as capacity. ``adopt`` grows the inventory when
    a base-topology node (never leased) drains into the pool."""

    def __init__(self, inventory: Dict[str, int], *,
                 classes: Optional[Dict[str, NodeClass]] = None,
                 storage: str = "ssd", provision_scale: float = 1.0):
        self.classes: Dict[str, NodeClass] = dict(NODE_CLASSES)
        if classes:
            self.classes.update(classes)
        unknown = set(inventory) - set(self.classes)
        assert not unknown, f"unknown node classes: {sorted(unknown)}"
        self.free: Dict[str, int] = {
            name: int(n) for name, n in inventory.items()}
        self.leases: Dict[str, str] = {}     # iid -> class name
        self.storage = storage
        # tests/benchmarks compress the Fig. 13 provisioning timeline
        # the same way chaos runs compress heartbeat/recovery delays
        self.provision_scale = float(provision_scale)
        self.n_leased = 0
        self.n_released = 0
        self.n_adopted = 0
        self.n_denied = 0

    def total_free(self) -> int:
        return sum(self.free.values())

    def _pick(self, role: str) -> Optional[str]:
        def bias_rank(name: str) -> Tuple[int, str]:
            b = self.classes[name].role_bias
            return (0 if b == role else (1 if b == "" else 2), name)
        cands = sorted((n for n, k in self.free.items() if k > 0),
                       key=bias_rank)
        return cands[0] if cands else None

    def lease(self, role: str, iid: str) -> Optional[NodeClass]:
        name = self._pick(role)
        if name is None:
            self.n_denied += 1
            return None
        self.free[name] -= 1
        self.leases[iid] = name
        self.n_leased += 1
        return self.classes[name]

    def release(self, iid: str) -> bool:
        name = self.leases.pop(iid, None)
        if name is None:
            return False
        self.free[name] = self.free.get(name, 0) + 1
        self.n_released += 1
        return True

    def adopt(self, ncls_name: str = "balanced"):
        """A base-topology node decommissioned into the shared pool."""
        name = ncls_name if ncls_name in self.classes else "balanced"
        self.free[name] = self.free.get(name, 0) + 1
        self.n_adopted += 1

    def provision_delay(self, ncls: NodeClass) -> float:
        return self.provision_scale * substitute_ready_delay(
            ncls.provision_level, storage=self.storage)

    def ledger(self) -> Dict[str, float]:
        return {
            "pool_free": float(self.total_free()),
            "pool_leased": float(len(self.leases)),
            "pool_leases_total": float(self.n_leased),
            "pool_releases_total": float(self.n_released),
            "pool_adopted": float(self.n_adopted),
            "pool_denied": float(self.n_denied),
        }


@dataclass
class ScaleOp:
    """One provision (up) or drain+decommission (down) transition; the
    payload of the ``scale`` events on the group heap."""
    kind: str               # "up" | "down"
    role: str               # "P" | "D"
    gid: str
    iid: str
    ncls: str               # node-class name
    t_start: float
    t_ready: float          # up: provisioning completes (substitute
    #                         timeline); down: first drain re-check
    t_done: float = -1.0


class AutoScaler:
    """Goodput-maximizing group scaler on the frontend's event loop.

    ``frontend.serve()`` calls ``step(now)`` every ``period_s`` virtual
    seconds (alongside the ratio adjusters); ``on_event`` receives the
    group-heap ``scale`` events this scaler schedules. All inputs are
    event-clock state — deterministic given the arrival schedule."""

    def __init__(self, frontend, pool: NodePool,
                 slos, *, period_s: float = 0.25, window_s: float = 2.0,
                 min_each: int = 1, up_margin: float = 0.9,
                 down_margin: float = 0.5, cooldown_s: float = 0.5,
                 drain_recheck_s: float = 0.02,
                 max_group_nodes: Optional[int] = None):
        self.fe = frontend
        self.pool = pool
        if isinstance(slos, SLOSpec):
            slos = {sc: slos for sc in frontend.groups}
        self.slos: Dict[str, SLOSpec] = dict(slos)
        self.period_s = float(period_s)
        self.window_s = float(window_s)
        self.min_each = int(min_each)
        self.up_margin = float(up_margin)
        self.down_margin = float(down_margin)
        self.cooldown_s = float(cooldown_s)
        self.drain_recheck_s = float(drain_recheck_s)
        self.max_group_nodes = max_group_nodes
        self._arrivals: Dict[str, List[float]] = {}
        self._cool: Dict[str, float] = {}
        self._wake: Dict[str, bool] = {}
        self._n_ops = 0
        self.ops: List[ScaleOp] = []
        self._led: Dict[str, Dict[str, float]] = {}
        frontend.attach_autoscaler(self)

    # ------------------------------------------------------ telemetry
    def note_arrival(self, scenario: str, t: float,
                     gen_tokens: int = -1):
        xs = self._arrivals.setdefault(scenario, [])
        xs.append((t, int(gen_tokens)))
        if len(xs) > 2048:
            del xs[:-1024]

    def _rate(self, scenario: str, t: float) -> float:
        xs = self._arrivals.get(scenario, ())
        lo = t - self.window_s
        return sum(1 for x, _ in xs if x > lo) / self.window_s

    def _gen_est(self, scenario: str, t: float) -> Optional[float]:
        """Expected output length of the CURRENT tide: the declared
        ``max_new_tokens`` of arrivals in the rate window. Finished-
        request history lags a tide change by a whole generation (a
        decode-bound burst looks prefill-bound until its first requests
        complete); the declared budget is known at submission. A
        declared 0 (prefill-complete scoring) counts — only undeclared
        (-1) arrivals are skipped. None when the window is empty."""
        lo = t - self.window_s
        gens = [g for x, g in self._arrivals.get(scenario, ())
                if x > lo and g >= 0]
        return _mean(gens) if gens else None

    def _ledger(self, gid: str) -> Dict[str, float]:
        return self._led.setdefault(gid, {
            "scale_up_started": 0.0, "scale_up_done": 0.0,
            "scale_down_started": 0.0, "scale_down_done": 0.0,
            "scale_denied": 0.0})

    def group_ledger(self, gid: str) -> Dict[str, float]:
        out = dict(self._ledger(gid))
        g = next((g for g in self.fe.groups.values() if g.gid == gid),
                 None)
        out["scale_in_flight"] = float(
            g is not None and g.scale_op is not None)
        return out

    def ledger(self) -> Dict[str, float]:
        out = self.pool.ledger()
        for led in self._led.values():
            for k, v in led.items():
                out[k] = out.get(k, 0.0) + v
        return out

    # ----------------------------------------------------- goodput law
    def _live(self, nodes):
        return [n for n in nodes
                if not (n.draining or n.crashed or n.ejected)]

    def _eff(self, nodes, role: str) -> float:
        return sum(1.0 / max(n.prefill_scale if role == "P"
                             else n.decode_scale, 1e-9)
                   for n in nodes)

    def _model(self, g, t: float) -> Optional[GoodputModel]:
        slo = self.slos.get(g.scenario)
        if slo is None:
            return None
        bs = max((p.batch_size for p in g.prefills), default=4)
        slots = max((d.engine.max_slots for d in g.decodes), default=8)
        ge = self._gen_est(g.scenario, t)
        gen = ge if ge is not None else (_mean(g.gen_tokens[-64:]) or 8.0)
        stats = dict(g.transfer_stats())
        # the control loop wants FRESH service times — a tide change
        # (long-prompt -> short-prompt traffic) must reprice capacity
        # within a few batches, not after the 32-sample median turns
        # over. Reads the raw per-group ledgers; transfer_stats() and
        # its [-32:] medians are untouched.
        pb = sorted(g.prefill_batch_s[-8:])
        ds = sorted(g.decode_step_s[-8:])
        if pb:
            stats["prefill_batch_median_s"] = pb[len(pb) // 2]
        if ds:
            stats["decode_step_median_s"] = ds[len(ds) // 2]
        return GoodputModel.from_stats(
            slo, stats, batch_size=bs, decode_slots=slots,
            gen_tokens=gen)

    # ----------------------------------------------------------- step
    def step(self, t: float):
        for g in self.fe.groups.values():
            self._step_group(t, g)
            self._arm_wake(t, g)

    def _arm_wake(self, t: float, g):
        """Self-schedule a periodic ``scale`` wake on the group heap
        while this group holds pool leases or an in-flight op: the event
        clock only advances on events, so without a wake an idle lull
        would never reach the scaler and borrowed nodes would squat on
        the pool until the next arrival. The wake chain stops as soon as
        nothing is leased, so a drained timeline still terminates."""
        if self._wake.get(g.gid):
            return
        holding = g.scale_op is not None or any(
            iid.startswith(g.gid + "/") for iid in self.pool.leases)
        if holding:
            self._wake[g.gid] = True
            g.schedule(t + self.period_s, "scale", None)

    def _step_group(self, t: float, g):
        if g.scale_op is not None:          # one transition at a time
            return
        if t < self._cool.get(g.gid, 0.0):
            return
        model = self._model(g, t)
        if model is None:                   # no SLO / no samples yet
            return
        backlog = self.fe.queued_backlog(g.scenario)
        demand = self._rate(g.scenario, t) + backlog / self.window_s
        live_p = self._live(g.prefills)
        live_d = self._live(g.decodes)
        cap_p = model.prefill_capacity(self._eff(live_p, "P"))
        cap_d = model.decode_capacity(self._eff(live_d, "D"))
        if demand > self.up_margin * min(cap_p, cap_d):
            if self.max_group_nodes is not None and \
                    len(g.prefills) + len(g.decodes) >= self.max_group_nodes:
                return
            role = "P" if cap_p <= cap_d else "D"
            self._scale_up(t, g, role)
            return
        if backlog > 0:
            return                          # queued work: never shrink
        for role, cap_fn, live in (("P", model.prefill_capacity, live_p),
                                   ("D", model.decode_capacity, live_d)):
            if len(live) <= self.min_each:
                continue
            victim = self._victim(live, role)
            v_eff = 1.0 / max(victim.prefill_scale if role == "P"
                              else victim.decode_scale, 1e-9)
            if demand < self.down_margin * cap_fn(
                    self._eff(live, role) - v_eff):
                self._scale_down(t, g, role, victim)
                return

    def _victim(self, live, role: str):
        """Least-loaded node, pool-leased nodes first (borrowed capacity
        returns to the shared pool before the base topology shrinks)."""
        def key(n):
            load = (len(n.forming) + len(n.waiting)) if role == "P" \
                else len(n.requests)
            return (0 if n.iid in self.pool.leases else 1, load, n.iid)
        return min(live, key=key)

    # ----------------------------------------------------- transitions
    def _scale_up(self, t: float, g, role: str):
        led = self._ledger(g.gid)
        iid = f"{g.gid}/S{self._n_ops}"
        ncls = self.pool.lease(role, iid)
        if ncls is None:
            # pool exhausted: degradation falls through to absorb /
            # backpressure / shed at the gateway
            led["scale_denied"] += 1
            return
        self._n_ops += 1
        delay = self.pool.provision_delay(ncls)
        op = ScaleOp("up", role, g.gid, iid, ncls.name,
                     t_start=t, t_ready=t + delay)
        self._track(op)
        g.scale_op = op
        led["scale_up_started"] += 1
        g.schedule(t + delay, "scale", op)

    def _scale_down(self, t: float, g, role: str, victim):
        self._n_ops += 1
        victim.draining = True
        victim.decommissioning = True
        op = ScaleOp("down", role, g.gid, victim.iid, victim.node_class,
                     t_start=t, t_ready=t + self.drain_recheck_s)
        self._track(op)
        g.scale_op = op
        self._ledger(g.gid)["scale_down_started"] += 1
        g.schedule(t + self.drain_recheck_s, "scale", op)

    def _track(self, op: ScaleOp):
        self.ops.append(op)
        if len(self.ops) > 512:
            del self.ops[:-256]

    def on_event(self, t: float, g, op: Optional[ScaleOp]):
        """A ``scale`` event fired on the group heap."""
        if op is None:                      # periodic wake (see _arm_wake)
            self._wake[g.gid] = False
            self._step_group(t, g)
            self._arm_wake(t, g)
            return
        if op.kind == "up":
            g.add_node(t, op.role, iid=op.iid,
                       ncls=self.pool.classes[op.ncls])
            op.t_done = t
            g.scale_op = None
            self._ledger(g.gid)["scale_up_done"] += 1
            self._cool[g.gid] = t + self.cooldown_s
            return
        node = g.find_node(op.iid)
        if node is not None:
            if not node.crashed and not node.draining:
                # the fault controller rebooted it mid-drain (fresh
                # flags): re-mark and keep draining toward decommission
                node.draining = True
                node.decommissioning = True
            if node.crashed or not g.node_drained(node):
                # a crashed node is NEVER released to the pool here —
                # the lease waits for its substitute to reboot and drain
                g.schedule(t + self.drain_recheck_s, "scale", op)
                return
            g.remove_node(t, node)
        if not self.pool.release(op.iid):
            self.pool.adopt(op.ncls)    # base-topology node joins the pool
        op.t_done = t
        g.scale_op = None
        self._ledger(g.gid)["scale_down_done"] += 1
        self._cool[g.gid] = t + self.cooldown_s
