"""In-process mini-cluster: the REAL data path, end to end.

Gateway (on-demand rejection forwarding) -> PrefillEngine (real forward)
-> block-free KVCache transfer between actual paged pools (Pallas
gather/RecvScatter) -> DecodeEngine (paged continuous batching) ->
streamed tokens. Used by examples/ and the integration tests; cluster-SCALE
behavior is the discrete-event simulator's job (repro.core.cluster_sim).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax

from repro.core.transfer import KVTransferEngine, LinkModel
from repro.core.zookeeper import MetaStore
from repro.models.config import ModelConfig
from repro.models.params import init_params
from repro.serving.engine import DecodeEngine, PrefillEngine, PrefillOutput
from repro.serving.kvcache import PagedKVPool


@dataclass
class ServeRequest:
    rid: int
    tokens: List[int]
    max_new_tokens: int = 16
    generated: List[int] = field(default_factory=list)
    done: bool = False
    on_token: Optional[Callable[[int], None]] = None  # SSE stream
    frames: Optional[object] = None  # enc-dec: stub frontend embeddings


class PrefillNode:
    def __init__(self, iid: str, cfg: ModelConfig, params, *,
                 num_blocks: int = 128, block_size: int = 16,
                 batch_size: int = 4):
        self.iid = iid
        self.engine = PrefillEngine(cfg, params)
        self.pool = PagedKVPool(cfg, num_blocks=num_blocks,
                                block_size=block_size)
        self.batch_size = batch_size
        self.forming: List[ServeRequest] = []
        self.waiting: List[Tuple[ServeRequest, PrefillOutput]] = []
        self.sse_connections = 0

    def idle(self) -> bool:
        return (len(self.forming) < self.batch_size
                and len(self.waiting) < self.batch_size)

    def offer(self, req: ServeRequest) -> bool:
        if not self.idle():
            return False
        self.forming.append(req)
        self.sse_connections += 1
        return True

    def run_batch(self) -> List[Tuple[ServeRequest, PrefillOutput]]:
        if not self.forming:
            return []
        batch = self.forming
        self.forming = []
        frames = ([r.frames for r in batch]
                  if batch and batch[0].frames is not None else None)
        outs = self.engine.run([r.tokens for r in batch], frames=frames)
        ready = []
        for req, out in zip(batch, outs):
            req.generated.append(out.first_token)
            if req.on_token:
                req.on_token(out.first_token)
            if out.k is not None:
                blocks = self.pool.alloc(req.rid, out.prompt_len)
                self.pool.write_prefill(blocks, out.k, out.v)
            ready.append((req, out))
        self.waiting.extend(ready)
        return ready


class DecodeNode:
    def __init__(self, iid: str, cfg: ModelConfig, params, *,
                 num_blocks: int = 256, block_size: int = 16,
                 max_slots: int = 8):
        self.iid = iid
        self.pool = PagedKVPool(cfg, num_blocks=num_blocks,
                                block_size=block_size)
        self.engine = DecodeEngine(cfg, params, self.pool,
                                   max_slots=max_slots)
        self.requests: Dict[int, ServeRequest] = {}

    def can_admit(self) -> bool:
        return bool(self.engine.free_slots())

    def admit(self, req: ServeRequest, out: PrefillOutput,
              src_pool: PagedKVPool, xfer: KVTransferEngine,
              *, mode: str = "block_free"):
        # allocate room for prompt + all new tokens, move KV block-free
        total = out.prompt_len + req.max_new_tokens + 1
        dst_blocks = self.pool.alloc(req.rid, total)
        if out.k is not None:
            src_blocks = src_pool.owned(req.rid)
            n = len(src_blocks)
            if mode == "block_free":
                xfer.transfer_block_free(src_pool, src_blocks, self.pool,
                                         dst_blocks[:n])
            else:
                xfer.transfer_block_fixed(src_pool, src_blocks, self.pool,
                                          dst_blocks[:n])
            src_pool.release(req.rid)
        self.engine.admit(req.rid, out, self.pool.owned(req.rid))
        self.requests[req.rid] = req

    def step(self):
        res = self.engine.step()
        for slot, tok in res.items():
            rid = self.engine.rid[slot]
            req = self.requests[rid]
            req.generated.append(tok)
            if req.on_token:
                req.on_token(tok)
            if len(req.generated) >= req.max_new_tokens + 1:
                req.done = True
                self.engine.evict(slot)
                self.pool.release(rid)
                del self.requests[rid]


class MiniCluster:
    """One P/D group with real compute, stepped synchronously."""

    def __init__(self, cfg: ModelConfig, *, n_prefill: int = 1,
                 n_decode: int = 1, seed: int = 0,
                 transfer_mode: str = "block_free",
                 params=None, link: LinkModel = LinkModel()):
        self.cfg = cfg
        if params is None:
            params = init_params(cfg, jax.random.PRNGKey(seed))
        self.params = params
        self.meta = MetaStore()
        self.meta.register_group("g0", "default")
        self.prefills = [PrefillNode(f"P{i}", cfg, params)
                         for i in range(n_prefill)]
        self.decodes = [DecodeNode(f"D{i}", cfg, params)
                        for i in range(n_decode)]
        for p in self.prefills:
            self.meta.gather_instance(0.0, p.iid, "P", "g0")
        for d in self.decodes:
            self.meta.gather_instance(0.0, d.iid, "D", "g0")
        self.xfer = KVTransferEngine(link, seed=seed)
        self.transfer_mode = transfer_mode
        self.pending: List[ServeRequest] = []
        self.rejections = 0

    # ---------------------------------------------------------- ingress
    def submit(self, req: ServeRequest):
        self.pending.append(req)

    # ------------------------------------------------------------- tick
    def tick(self):
        # 1. gateway: on-demand forwarding, least-SSE first, retries
        still: List[ServeRequest] = []
        for req in self.pending:
            placed = False
            for p in sorted(self.prefills, key=lambda x: x.sse_connections):
                if p.offer(req):
                    placed = True
                    break
                self.rejections += 1
            if not placed:
                still.append(req)   # waits at the gateway
        self.pending = still
        # 2. prefill batches
        for p in self.prefills:
            p.run_batch()
        # 3. transfer to decode (async retrieval, least-loaded decode)
        for p in self.prefills:
            remaining = []
            for req, out in p.waiting:
                tgt = min((d for d in self.decodes if d.can_admit()),
                          key=lambda d: len(d.requests), default=None)
                if tgt is None:
                    remaining.append((req, out))
                    continue
                tgt.admit(req, out, p.pool, self.xfer,
                          mode=self.transfer_mode)
                p.sse_connections -= 1
            p.waiting = remaining
        # 4. decode iteration
        for d in self.decodes:
            d.step()

    def run(self, requests: Sequence[ServeRequest], *,
            max_ticks: int = 200) -> List[ServeRequest]:
        for r in requests:
            self.submit(r)
        for _ in range(max_ticks):
            self.tick()
            if all(r.done for r in requests):
                break
        return list(requests)
