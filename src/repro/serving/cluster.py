"""In-process mini-cluster nodes: the REAL data path, end to end.

PrefillNode (real forward into a paged pool, streaming per-layer KV in
overlapped mode) -> block-free KVCache transfer between actual paged
pools (Pallas gather/RecvScatter; overlapped layer-wise pipeline via
repro.serving.transfer_sched by default, blocking in-tick transfer
otherwise) -> DecodeNode (paged continuous batching) -> streamed
tokens. The gateway over these nodes is the scenario-aware multi-group
ClusterFrontend in repro.serving.frontend; MiniCluster below is its
single-group compatibility shim. Cluster-SCALE behavior is the
discrete-event simulator's job (repro.core.cluster_sim).
"""
from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.transfer import KVTransferEngine, LinkModel
from repro.models.config import ModelConfig
from repro.serving.engine import DecodeEngine, PrefillEngine, PrefillOutput
from repro.serving.kvcache import PagedKVPool


def _frames_ns(req: "ServeRequest") -> Optional[str]:
    """Prefix-index namespace for enc-dec requests: decoder self-attn KV
    depends on the encoder output, so prefixes are shareable only between
    requests with byte-identical frames. The digest is memoized on the
    request (ingress affinity probes every prefill node)."""
    if req.frames is None:
        return None
    ns = getattr(req, "_frames_digest", None)
    if ns is None:
        ns = hashlib.sha1(np.asarray(req.frames).tobytes()).hexdigest()
        req._frames_digest = ns
    return ns


@dataclass
class ServeRequest:
    rid: int
    tokens: List[int]
    max_new_tokens: int = 16
    generated: List[int] = field(default_factory=list)
    done: bool = False
    on_token: Optional[Callable[[int], None]] = None  # SSE stream
    frames: Optional[object] = None  # enc-dec: stub frontend embeddings
    scenario: str = "default"        # routes to the matching ServeGroup
    # virtual-second timeline stamps (set by the gateway / event core):
    submit_t: float = -1.0           # gateway arrival
    first_token_t: float = -1.0      # prefill batch completion (TTFT end)
    finish_t: float = -1.0           # last decode token (TPOT window end)
    # fault tolerance (serving/faults.py): an SLO deadline in virtual
    # seconds after submit (<0 == none); recovery sheds a request whose
    # deadline already passed instead of re-admitting it, and counts
    # every crash-driven re-prefill in ``readmits``
    slo_deadline_s: float = -1.0
    shed: bool = False
    readmits: int = 0
    # gateway overload control: placement attempts burned at the
    # ClusterFrontend (capped, seeded backoff mirrors the fault
    # controller's requeue policy)
    gw_attempts: int = 0


class PrefillNode:
    def __init__(self, iid: str, cfg: ModelConfig, params, *,
                 num_blocks: int = 128, block_size: int = 16,
                 batch_size: int = 4, prefix_cache: bool = True,
                 bucket_prefill: Optional[bool] = None):
        self.iid = iid
        self.engine = PrefillEngine(cfg, params,
                                    bucket_prefill=bucket_prefill)
        # every family participates in the prefix index now. Capacity
        # MoE hits are rounded down to capacity-window boundaries;
        # SSM/hybrid stacks cache recurrent-state snapshots alongside
        # their KV blocks and hit only at snapshot boundaries — the
        # snapshot stride is the lcm of the engine alignment (SSD
        # chunk / capacity window) and the pool block size, so every
        # boundary ends exactly at a whole cached block
        self.prefix_cache = bool(prefix_cache) \
            and self.engine.supports_prefix_reuse
        # snapshot emission/restore rides the reuse path: when reuse is
        # off (disabled, or gated off on a bucket_prefill=False engine —
        # see PrefillEngine.supports_prefix_reuse) cold runs skip it
        self.needs_state = self.prefix_cache \
            and self.engine.requires_state_restore
        self.prefix_align = self.engine.prefix_align
        self.snap_stride = 0
        if self.needs_state:
            self.prefix_align = math.lcm(self.prefix_align, block_size)
            self.snap_stride = self.prefix_align
        self.pool = PagedKVPool(cfg, num_blocks=num_blocks,
                                block_size=block_size,
                                enable_prefix_cache=self.prefix_cache)
        self.batch_size = batch_size
        self.forming: List[ServeRequest] = []
        self.waiting: List[Tuple[ServeRequest, PrefillOutput]] = []
        self.sse_connections = 0
        self.draining = False        # pending role flip: no new traffic
        self.decommissioning = False # draining back into the node pool
        self.crashed = False         # fault-injected: memory/work lost
        self.ejected = False         # health-timeout removal (hang)
        self.hung_until = 0.0        # straggling until this virtual time
        self.busy_until = 0.0        # virtual time the node frees up
        # heterogeneous node-class identity (core.profiles.NodeClass):
        # virtual service-time multipliers charged by the event core —
        # the executed compute (and the token stream) is class-invariant
        self.node_class = "balanced"
        self.prefill_scale = 1.0
        self.decode_scale = 1.0
        self._batch_evt = False      # a "batch" event is already queued
        self._evictions_seen = 0     # pool evictions already ledgered
        # layer-streaming mode (overlapped transfer): per-rid payloads
        # {attn_layer -> (tokens, width) kv stripe} and batch timing
        self.staged: Dict[int, Dict[int, object]] = {}
        self.batch_meta: Dict[int, Tuple[float, float]] = {}

    def idle(self) -> bool:
        return (len(self.forming) < self.batch_size
                and len(self.waiting) < self.batch_size)

    def offer(self, req: ServeRequest) -> bool:
        if self.draining or self.crashed or self.ejected \
                or not self.idle():
            return False
        self.forming.append(req)
        self.sse_connections += 1
        return True

    def prefix_affinity(self, req: ServeRequest) -> int:
        """Cached-prefix token count this node could reuse for req
        (read-only; the group's ingress prefers the longest match)."""
        if not self.prefix_cache:
            return 0
        return self.pool.peek_prefix(req.tokens,
                                     namespace=_frames_ns(req),
                                     align=self.prefix_align,
                                     require_state=self.needs_state)

    def prefix_stats(self) -> Dict[str, float]:
        return {
            "lookups": self.pool.lookups, "hits": self.pool.hits,
            "hit_tokens": self.pool.hit_tokens,
            "evictions": self.pool.evictions,
            "cow_copies": self.pool.cow_copies,
            "compute_tokens": self.engine.compute_tokens,
            "reused_tokens": self.engine.reused_tokens,
            "snap_hits": self.pool.snap_hits,
            "snap_misses": self.pool.snap_misses,
            "snap_stores": self.pool.snap_stores,
            "snap_bytes": self.pool.snap_bytes,
            "state_restores": self.engine.state_restores,
        }

    def run_batch(self, collect_layers: bool = False
                  ) -> List[Tuple[ServeRequest, PrefillOutput]]:
        if not self.forming:
            return []
        batch = self.forming
        self.forming = []
        ready: List[Tuple[ServeRequest, PrefillOutput]] = []
        cold: List[ServeRequest] = []
        warm: List[Tuple[ServeRequest, int]] = []
        for req in batch:
            cached = 0
            if self.prefix_cache:
                cached = self.pool.acquire_prefix(
                    req.rid, req.tokens, namespace=_frames_ns(req),
                    align=self.prefix_align,
                    require_state=self.needs_state)
            (warm.append((req, cached)) if cached else cold.append(req))

        def _stash_for(rid):
            def cb(_i, li, k_li, v_li, _frac):
                self.staged.setdefault(rid, {})[li] = jnp.concatenate(
                    [k_li, v_li], axis=-1)
            return cb

        if cold:
            frames = ([r.frames for r in cold]
                      if cold[0].frames is not None else None)
            on_layer = None
            if collect_layers:
                def on_layer(i, li, k_li, v_li, frac):
                    _stash_for(cold[i].rid)(i, li, k_li, v_li, frac)
            outs = self.engine.run([r.tokens for r in cold], frames=frames,
                                   on_layer=on_layer,
                                   snap_stride=self.snap_stride)
            for req, out in zip(cold, outs):
                if out.k is not None:
                    blocks = self.pool.alloc(req.rid, out.prompt_len)
                    self.pool.write_prefill(blocks, out.k, out.v)
                elif self.prefix_cache and self.needs_state:
                    # attn-free: zero-width blocks are trie key-holders
                    # for the boundary snapshots
                    self.pool.alloc(req.rid, out.prompt_len)
                if self.prefix_cache and self.pool.owned(req.rid):
                    self.pool.insert_prefix(
                        req.rid, req.tokens,
                        namespace=_frames_ns(req),
                        states=out.snapshots)
                ready.append((req, out))
        for req, cached in warm:
            # hit: gather the cached prefix KV (Pallas kv_gather) and —
            # for SSM/hybrid — the boundary state snapshot, run the
            # forward over only the uncached suffix, write the suffix KV
            # into freshly allocated blocks (shared blocks stay read-only)
            pre_blocks = self.pool.owned(req.rid)
            buf = None
            if self.pool.attn_layers:
                buf = self.pool.gather_contiguous(pre_blocks)[:, :cached]
            state = self.pool.snapshot_for(req.rid, cached) \
                if self.needs_state else None
            out = self.engine.run_suffix(
                req.tokens[cached:], buf, frames=req.frames,
                on_layer=_stash_for(req.rid) if collect_layers else None,
                state=state, prefix_len=cached,
                snap_stride=self.snap_stride)
            self.pool.alloc_to(req.rid, out.prompt_len)
            if out.k is not None:
                self.pool.write_tokens(self.pool.owned(req.rid), cached,
                                       out.k[:, cached:], out.v[:, cached:])
            self.pool.insert_prefix(req.rid, req.tokens,
                                    namespace=_frames_ns(req),
                                    states=out.snapshots)
            ready.append((req, out))
        order = {id(r): i for i, r in enumerate(batch)}
        ready.sort(key=lambda pair: order[id(pair[0])])
        for req, out in ready:
            req.generated.append(out.first_token)
            if req.on_token:
                req.on_token(out.first_token)
        self.waiting.extend(ready)
        return ready


class DecodeNode:
    def __init__(self, iid: str, cfg: ModelConfig, params, *,
                 num_blocks: int = 256, block_size: int = 16,
                 max_slots: int = 8, fused: Optional[bool] = None,
                 spec=None):
        self.iid = iid
        self.cfg = cfg
        self.params = params
        self.pool = PagedKVPool(cfg, num_blocks=num_blocks,
                                block_size=block_size)
        self.engine = DecodeEngine(cfg, params, self.pool,
                                   max_slots=max_slots, fused=fused,
                                   spec=spec)
        self.requests: Dict[int, ServeRequest] = {}
        self.draining = False        # pending role flip: no new traffic
        self.decommissioning = False # draining back into the node pool
        self.crashed = False         # fault-injected: memory/work lost
        self.ejected = False         # health-timeout removal (hang)
        self.hung_until = 0.0        # straggling until this virtual time
        self.busy_until = 0.0        # virtual time the node frees up
        self.node_class = "balanced"
        self.prefill_scale = 1.0     # chunked-prefill absorption cost
        self.decode_scale = 1.0
        self._step_evt = False       # a "step" event is already queued
        # DynaServe-style elasticity: a lazily built PrefillEngine over
        # the SAME params lets this node absorb chunked prefill work
        # during a spike (serving/frontend.py schedules the chunks
        # between decode steps); at most one absorb job in flight
        self._absorber: Optional[PrefillEngine] = None
        self._absorb_job: Optional[object] = None

    def absorber(self) -> PrefillEngine:
        if self._absorber is None:
            self._absorber = PrefillEngine(self.cfg, self.params)
        return self._absorber

    def can_admit(self) -> bool:
        return not (self.draining or self.crashed or self.ejected) \
            and bool(self.engine.free_slots())

    def free_slot_count(self) -> int:
        return len(self.engine.free_slots())

    def admit(self, req: ServeRequest, out: PrefillOutput,
              src_pool: PagedKVPool, xfer: KVTransferEngine,
              *, mode: str = "block_free"):
        """Synchronous (blocking) admission: the whole KVCache moves in
        the caller's critical section. The overlapped path instead runs
        through TransferScheduler, which allocates dst blocks up front,
        scatters per-layer stripes as they land and calls finish_admit
        when the last one does."""
        # allocate room for prompt + all new tokens, move KV block-free
        total = out.prompt_len + req.max_new_tokens + 1
        dst_blocks = self.pool.alloc(req.rid, total)
        if out.k is not None:
            src_blocks = src_pool.owned(req.rid)
            n = len(src_blocks)
            if mode == "block_free":
                xfer.transfer_block_free(src_pool, src_blocks, self.pool,
                                         dst_blocks[:n])
            else:
                xfer.transfer_block_fixed(src_pool, src_blocks, self.pool,
                                          dst_blocks[:n])
        # attn-free requests may still hold prefix-index key blocks on
        # the source pool (snapshot holders): always release
        src_pool.release(req.rid)
        self.finish_admit(req, out)

    def finish_admit(self, req: ServeRequest, out: PrefillOutput):
        """Attach an already-transferred request (KV in self.pool, mamba
        state / cross KV rides on ``out``) to a decode slot. In spec
        mode the engine additionally needs the prompt tokens: the draft
        model's prefill runs at THIS node (only the target's KV crossed
        the wire)."""
        prompt = list(req.tokens) if self.engine.spec is not None else None
        self.engine.admit(req.rid, out, self.pool.owned(req.rid),
                          prompt=prompt)
        self.requests[req.rid] = req

    def step(self) -> List[ServeRequest]:
        """One continuous-batching iteration. Returns the requests that
        finished during it (so the event core can stamp finish times and
        fire freed-capacity events). A step retires ONE token per slot
        on the plain path and 1..k+1 on the speculative path; bursts
        are truncated at the request's token budget (greedy speculation
        is lossless, so a truncated burst is exactly the greedy
        stream's prefix)."""
        res = self.engine.step()
        finished: List[ServeRequest] = []
        for slot, toks in res.items():
            rid = self.engine.rid[slot]
            req = self.requests[rid]
            budget = req.max_new_tokens + 1 - len(req.generated)
            for tok in ([toks] if isinstance(toks, int) else toks)[:budget]:
                req.generated.append(tok)
                if req.on_token:
                    req.on_token(tok)
            if len(req.generated) >= req.max_new_tokens + 1:
                req.done = True
                self.engine.evict(slot)
                self.pool.release(rid)
                del self.requests[rid]
                finished.append(req)
        return finished


class MiniCluster:
    """One P/D group with real compute, stepped synchronously.

    Thin single-group compatibility shim over the scenario-aware
    repro.serving.frontend.ClusterFrontend: every request lands in one
    anonymous "default" group, so the legacy flat instance ids (P0, D0,
    ...) and the g0 group name are preserved for callers."""

    def __init__(self, cfg: ModelConfig, *, n_prefill: int = 1,
                 n_decode: int = 1, seed: int = 0,
                 transfer_mode: str = "block_free",
                 params=None, link: LinkModel = LinkModel(),
                 overlap_transfer: bool = True, tickless: bool = True):
        from repro.serving.frontend import ClusterFrontend  # import cycle
        self.frontend = ClusterFrontend(
            cfg, topology={"default": (n_prefill, n_decode)}, seed=seed,
            transfer_mode=transfer_mode, params=params, link=link,
            flat_iids=True, overlap_transfer=overlap_transfer,
            tickless=tickless)
        self.cfg = cfg
        self.params = self.frontend.params
        self.transfer_mode = transfer_mode

    @property
    def meta(self):
        return self.frontend.meta

    @property
    def xfer(self):
        return self.frontend.xfer

    @property
    def prefills(self):
        return self.frontend.groups["default"].prefills

    @property
    def decodes(self):
        return self.frontend.groups["default"].decodes

    @property
    def pending(self) -> List[ServeRequest]:
        return self.frontend.pending

    @property
    def rejections(self) -> int:
        return self.frontend.rejections

    def submit(self, req: ServeRequest):
        self.frontend.submit(req)

    def tick(self):
        self.frontend.tick()

    def run(self, requests: Sequence[ServeRequest], *,
            max_ticks: int = 200) -> List[ServeRequest]:
        return self.frontend.run(requests, max_ticks=max_ticks)
