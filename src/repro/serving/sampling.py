"""Token sampling policies for the decode engines.

The parity tests and the paper's evaluation use greedy; temperature/top-k
are provided for completeness of the serving substrate.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(logits: jax.Array, key: jax.Array, *, temperature: float = 1.0,
           top_k: Optional[int] = None) -> jax.Array:
    """logits: (..., vocab). temperature <= 0 falls back to greedy."""
    if temperature <= 0.0:
        return greedy(logits)
    l32 = logits.astype(jnp.float32) / temperature
    if top_k is not None and top_k > 0 and top_k < l32.shape[-1]:
        kth = jnp.sort(l32, axis=-1)[..., -top_k][..., None]
        l32 = jnp.where(l32 < kth, -1e30, l32)
    return jax.random.categorical(key, l32, axis=-1).astype(jnp.int32)
