"""Asynchronous, layer-wise-triggered KV transfer pipeline (paper §3.6,
Fig. 10) on the REAL data path.

The synchronous path moves a request's whole linearized KVCache in one
blocking message inside decode admission. This scheduler instead
consumes the PrefillEngine's layer stream: layer ``i``'s stripe of the
contiguous block-free buffer becomes sendable the moment layer ``i`` is
computed (offset/length arithmetic per Fig. 10), so transfer hides
behind the remaining layers' prefill compute and decode admission fires
when the LAST layer lands — not inside the prefill tick's critical
section.

Mechanics, all in virtual (modeled link) time but with REAL byte
movement between paged pools so delivery is bit-exact testable:

  * one directional link per (src, dst) instance pair, at most ONE
    message in flight per link, FIFO contention queueing across jobs;
  * per-layer segments stamped with ready times from the engine's
    network-depth fractions x the batch's measured compute time;
  * multi-hop conflicts (LinkModel.hops > 1) fail a segment send, pay
    the conflict penalty and retry; after ``max_retries`` the job
    escalates to a different decode node;
  * a job whose target decode node drains or fails mid-transfer is
    requeued: partially-written dst blocks are released and every
    segment is re-sent (from the sender's linearized buffer) to a
    fallback node picked by the owner's ``pick_dst`` callback;
  * the mamba recurrent state / encoder-decoder cross-attention KV that
    must survive the P->D handoff travels as a final "state" payload
    segment alongside the KV stripes, so hybrid / attn-free / enc-dec
    archs ride the same pipeline;
  * an uncontended single job reports exactly
    ``LinkModel.per_layer_completion`` — the shared overlap model the
    discrete-event simulator uses (pinned by tests/test_transfer.py).

Since PR 7 this virtual clock is the spine of the whole serving loop:
``ServeGroup`` drains its own event heap (batches, hand-offs, decode
steps, flips, evictions) in lockstep with ``next_event()``/``pump()``
here, so segment landings interleave with compute events in global
nondecreasing virtual-time order (tests/test_event_loop.py).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.transfer import LinkModel, layer_slices


@dataclass
class Segment:
    """One message on the wire: a layer stripe of the linearized buffer,
    or the trailing state payload (layer == -1)."""
    layer: int                   # attn-layer row; -1 == state payload
    offset: int                  # byte offset in the linearized buffer
    nbytes: int
    ready_t: float               # virtual time the payload is producible
    start_t: float = -1.0
    done_t: float = -1.0
    retries: int = 0
    delivered: bool = False


class _Link:
    """Directional src->dst link: single in-flight message, FIFO queue."""

    __slots__ = ("key", "free_t", "in_flight", "queue", "history",
                 "busy_s", "n_msgs", "nbytes")

    def __init__(self, key: Tuple[str, str]):
        self.key = key
        self.free_t = 0.0
        self.in_flight: Optional[Tuple["TransferJob", Segment]] = None
        self.queue: List[Tuple["TransferJob", Segment]] = []
        self.history: List[Tuple[float, float]] = []   # (start, done) sends
        self.busy_s = 0.0
        self.n_msgs = 0
        self.nbytes = 0

    def drop_job(self, job: "TransferJob"):
        self.queue = [(j, s) for j, s in self.queue if j is not job]
        if self.in_flight is not None and self.in_flight[0] is job:
            self.in_flight = None


@dataclass
class TransferJob:
    rid: int
    req: object
    out: object                       # PrefillOutput
    src_iid: str
    dst: object                       # .iid / .pool / .draining
    dst_blocks: List[int]
    n_kv_blocks: int
    segments: List[Segment]
    buf: Dict[int, jax.Array]         # layer -> (padded tokens, width)
    t_start: float                    # prefill batch start (virtual)
    compute_s: float                  # measured prefill compute
    prefill_done_t: float
    on_admit: Optional[Callable[["TransferJob"], None]] = None
    admitted_t: float = -1.0
    state: str = "active"             # active | waiting_dst | admitted
    requeues: int = 0

    @property
    def admission_wait(self) -> float:
        """prefill-done -> decode-admitted (the paper's hidden latency)."""
        return max(0.0, self.admitted_t - self.prefill_done_t)

    @property
    def transfer_busy_s(self) -> float:
        return sum(s.done_t - s.start_t for s in self.segments
                   if s.delivered)


def state_payload_nbytes(out) -> int:
    """Wire bytes of the non-KV state that must survive the P->D
    handoff: mamba recurrent/conv state (hybrid & attn-free archs) and
    encoder-decoder cross-attention KV."""
    n = 0
    for st in (out.mamba_state or {}).values():
        for arr in st.values():
            n += np.asarray(arr).size * 4
    for xk, xv in (out.cross or {}).values():
        n += (np.asarray(xk).size + np.asarray(xv).size) * 4
    return n


class TransferScheduler:
    """Per-layer-triggered D2D transfer scheduler over real paged pools.

    Owner wires ``pick_dst`` (fallback decode-node selection for
    mid-transfer requeues) and passes destination objects exposing
    ``iid``, ``pool`` (PagedKVPool) and optionally ``draining``.
    """

    def __init__(self, link: LinkModel = LinkModel(), *, seed: int = 0,
                 max_retries: int = 4,
                 pick_dst: Optional[Callable[["TransferJob"],
                                             Optional[object]]] = None):
        self.link = link
        self.rng = random.Random(seed)
        self.max_retries = max_retries
        self.pick_dst = pick_dst
        self.links: Dict[Tuple[str, str], _Link] = {}
        self.jobs: List[TransferJob] = []
        self.waiting: List[TransferJob] = []      # requeued, no target yet
        self.completed: List[TransferJob] = []
        self.failed_nodes: set = set()
        self.now = 0.0
        # counters (monotonic — the completed/waits lists are windowed)
        self.n_admitted = 0
        self.n_retries = 0
        self.n_requeues = 0
        self.n_restores = 0               # failed nodes brought back
        self.n_flaps = 0                  # link outage windows injected
        self.n_src_failed = 0             # jobs killed by a src crash
        self.state_segments = 0           # trailing state payloads shipped
        self.state_bytes = 0              # ... and their wire bytes
        self.admission_waits: List[float] = []

    # ------------------------------------------------------------ intake
    def _link(self, src: str, dst: str) -> _Link:
        key = (src, dst)
        if key not in self.links:
            self.links[key] = _Link(key)
        return self.links[key]

    def begin(self, req, out, *, src_iid: str, dst, t_start: float = 0.0,
              compute_s: float = 0.0,
              payloads: Optional[Dict[int, jax.Array]] = None,
              fracs: Optional[Sequence[float]] = None,
              on_admit: Optional[Callable[["TransferJob"], None]] = None
              ) -> TransferJob:
        """Start the pipelined transfer of one prefilled request.

        ``payloads`` maps attn-layer index -> (tokens, width) KV stripe
        as streamed by PrefillEngine's layer mode; when omitted they are
        sliced from ``out.k``/``out.v``. ``fracs`` are the engine's
        network-depth layer fractions (uniform if omitted)."""
        rid = req.rid
        pool = dst.pool
        total = out.prompt_len + getattr(req, "max_new_tokens", 0) + 1
        dst_blocks = pool.alloc(rid, total)
        n_kv = pool.blocks_for_tokens(out.prompt_len) \
            if out.k is not None else 0
        segments: List[Segment] = []
        buf: Dict[int, jax.Array] = {}
        prefill_done = t_start + compute_s
        if n_kv:
            L = int(out.k.shape[0])
            if fracs is None:
                fracs = [(i + 1) / L for i in range(L)]
            stripe = pool.layer_nbytes(n_kv)
            slices = layer_slices(L, L * stripe)
            pad = n_kv * pool.block_size - out.prompt_len
            for li in range(L):
                if payloads is not None and li in payloads:
                    row = payloads[li]
                    if row.shape[-1] == out.k.shape[-1]:  # split k half only
                        row = jnp.concatenate([row, out.v[li]], axis=-1)
                else:
                    row = jnp.concatenate([out.k[li], out.v[li]], axis=-1)
                if pad:
                    row = jnp.pad(row, ((0, pad), (0, 0)))
                buf[li] = row
                off, ln = slices[li]
                segments.append(Segment(
                    layer=li, offset=off, nbytes=ln,
                    ready_t=t_start + fracs[li] * compute_s))
        state_bytes = state_payload_nbytes(out)
        if state_bytes:
            # the recurrent/cross state is only final once the whole
            # forward is done: it ships last, alongside the KV payload.
            # Warm (prefix-reuse) SSM admissions ship the RESTORED state
            # advanced over the suffix — out.mamba_state comes straight
            # from run_suffix's snapshot-seeded forward, never a
            # recompute of the cached prefix
            segments.append(Segment(
                layer=-1, offset=sum(s.nbytes for s in segments),
                nbytes=state_bytes, ready_t=prefill_done))
            self.state_segments += 1
            self.state_bytes += state_bytes
        job = TransferJob(
            rid=rid, req=req, out=out, src_iid=src_iid, dst=dst,
            dst_blocks=dst_blocks, n_kv_blocks=n_kv, segments=segments,
            buf=buf, t_start=t_start, compute_s=compute_s,
            prefill_done_t=prefill_done, on_admit=on_admit)
        self.jobs.append(job)
        if segments:
            link = self._link(src_iid, dst.iid)
            link.queue.extend((job, s) for s in segments)
        else:
            self._admit(job, prefill_done)
        return job

    # ---------------------------------------------------------- failures
    def fail_node(self, iid: str):
        """Mark a decode node dead: every active job targeting it is
        requeued at the next pump."""
        self.failed_nodes.add(iid)

    def restore_node(self, iid: str):
        """Inverse of fail_node: a recovered (or substituted) node may
        receive transfers again. Without this the failed set was
        one-way — a node that rejoined the group could never be a
        transfer target for the rest of the process lifetime."""
        if iid in self.failed_nodes:
            self.failed_nodes.discard(iid)
            self.n_restores += 1

    def fail_src(self, iid: str) -> List["TransferJob"]:
        """A SOURCE (prefill) node crashed: every unadmitted job it was
        feeding dies with it — unlike a dst failure there is nothing to
        re-send from, the linearized buffer lived on the dead node.
        Partially-written dst blocks are released; the caller re-admits
        the affected requests through a healthy prefill (re-prefill of
        prompt + tokens emitted so far)."""
        doomed = [j for j in self.jobs if j.src_iid == iid]
        for job in doomed:
            self._link(job.src_iid, job.dst.iid).drop_job(job)
            if job.state == "active":
                job.dst.pool.release(job.rid)
            job.dst_blocks = []
            job.state = "failed_src"
            job.buf = {}
            self.jobs.remove(job)
            if job in self.waiting:
                self.waiting.remove(job)
            self.n_src_failed += 1
        return doomed

    def flap_link(self, src: str, dst: str, t: float, duration: float):
        """Link outage window [t, t+duration): the in-flight message (if
        any) is lost and retransmitted once the link returns; queued
        segments wait it out. Deterministic — no RNG involved."""
        link = self._link(src, dst)
        link.free_t = max(link.free_t, t + duration)
        if link.in_flight is not None:
            _, seg = link.in_flight
            if seg.done_t > t - 1e-12:       # mid-wire: full retransmit
                seg.start_t = t + duration
                seg.done_t = seg.start_t + self.link.time(seg.nbytes, 1)
                if link.history:
                    link.history[-1] = (seg.start_t, seg.done_t)
                link.free_t = max(link.free_t, seg.done_t)
        self.n_flaps += 1

    def _dst_gone(self, job: TransferJob) -> bool:
        return (job.dst.iid in self.failed_nodes
                or bool(getattr(job.dst, "draining", False)))

    def _requeue(self, job: TransferJob):
        """Target drained/failed (or conflict retries exhausted):
        release partially-written dst blocks and re-send everything to a
        fallback node. Bit-exactness is free — segments re-send from the
        sender's linearized buffer, which the job owns."""
        self._link(job.src_iid, job.dst.iid).drop_job(job)
        job.dst.pool.release(job.rid)
        job.dst_blocks = []
        job.requeues += 1
        self.n_requeues += 1
        for s in job.segments:
            s.delivered = False
            s.retries = 0
            s.start_t = s.done_t = -1.0
        self._place(job)

    def _place(self, job: TransferJob):
        new_dst = self.pick_dst(job) if self.pick_dst else None
        if new_dst is None or new_dst.iid in self.failed_nodes:
            job.state = "waiting_dst"
            if job not in self.waiting:
                self.waiting.append(job)
            return
        pool = new_dst.pool
        total = job.out.prompt_len + getattr(job.req, "max_new_tokens",
                                             0) + 1
        job.dst = new_dst
        job.dst_blocks = pool.alloc(job.rid, total)
        job.state = "active"
        if job in self.waiting:
            self.waiting.remove(job)
        if job.segments:
            link = self._link(job.src_iid, new_dst.iid)
            link.queue.extend((job, s) for s in job.segments)
        else:
            self._admit(job, max(self.now, job.prefill_done_t))

    # -------------------------------------------------------------- pump
    def pump(self, until: float) -> List[TransferJob]:
        """Advance the virtual clock to ``until``: start queued sends,
        complete in-flight ones, retry conflicts, requeue orphans and
        fire admissions. Returns jobs admitted by this pump."""
        until = max(until, self.now)
        admitted: List[TransferJob] = []
        for job in [j for j in self.jobs if j.state == "active"
                    and self._dst_gone(j)]:
            self._requeue(job)
        for job in list(self.waiting):
            self._place(job)
        progressed = True
        while progressed:
            progressed = False
            # snapshot: a conflict-escalation requeue inside
            # _complete_send may create a NEW (src,dst) link mid-loop
            for link in list(self.links.values()):
                if link.in_flight is not None:
                    job, seg = link.in_flight
                    if seg.done_t <= until:
                        link.in_flight = None
                        progressed = True
                        self._complete_send(link, job, seg, admitted)
                    continue
                if not link.queue:
                    continue
                job, seg = link.queue[0]
                start = max(link.free_t, seg.ready_t)
                if start > until:
                    continue
                link.queue.pop(0)
                seg.start_t = start
                seg.done_t = start + self.link.time(seg.nbytes, 1)
                link.history.append((seg.start_t, seg.done_t))
                del link.history[:-512]
                link.free_t = seg.done_t
                link.in_flight = (job, seg)
                progressed = True
        self.now = until
        return admitted

    def _complete_send(self, link: _Link, job: TransferJob, seg: Segment,
                       admitted: List[TransferJob]):
        if self._dst_gone(job):
            self._requeue(job)
            return
        # multi-hop conflict: the send failed, pay the penalty and retry
        if self.link.hops > 1 and self.link.conflict_prob > 0 \
                and self.rng.random() < self.link.conflict_prob:
            self.n_retries += 1
            seg.retries += 1
            link.free_t = seg.done_t \
                + self.rng.uniform(0.3, 1.0) * self.link.conflict_penalty
            seg.start_t = seg.done_t = -1.0
            if seg.retries > self.max_retries:
                self._requeue(job)       # escalate off the conflicted path
            else:
                link.queue.insert(0, (job, seg))
            return
        link.busy_s += seg.done_t - seg.start_t
        link.n_msgs += 1
        link.nbytes += seg.nbytes
        seg.delivered = True
        if seg.layer >= 0:
            # RecvScatter of this layer's stripe into the dst blocks
            job.dst.pool.scatter_layer(job.buf[seg.layer],
                                       job.dst_blocks[:job.n_kv_blocks],
                                       seg.layer)
        # state payload (layer == -1) rides on job.out and is applied at
        # admission (DecodeEngine.admit): only its wire time is modeled
        if all(s.delivered for s in job.segments):
            self._admit(job, max(seg.done_t, job.prefill_done_t))
            admitted.append(job)

    def _admit(self, job: TransferJob, t: float):
        job.admitted_t = t
        job.state = "admitted"
        if job in self.jobs:
            self.jobs.remove(job)
        self.n_admitted += 1
        self.completed.append(job)
        del self.completed[:-512]
        self.admission_waits.append(job.admission_wait)
        del self.admission_waits[:-512]
        if job.on_admit:
            job.on_admit(job)
        # everything is scattered into the dst pool (and the state
        # payload applied at admission): drop the wire buffer and the
        # PrefillOutput so the completed-jobs window pins no KV copies
        job.buf = {}
        job.out = None

    # ----------------------------------------------------------- queries
    def next_event(self) -> Optional[float]:
        """Earliest virtual time at which pump() can make progress."""
        best: Optional[float] = None
        for link in self.links.values():
            if link.in_flight is not None:
                cand = link.in_flight[1].done_t
            elif link.queue:
                _, seg = link.queue[0]
                cand = max(link.free_t, seg.ready_t) \
                    + self.link.time(seg.nbytes, 1)
            else:
                continue
            best = cand if best is None else min(best, cand)
        return best

    def pending_for(self, iid: str) -> int:
        return sum(1 for j in self.jobs
                   if j.state == "active" and j.dst.iid == iid)

    def idle(self) -> bool:
        return not self.jobs and not self.waiting

    def stats(self) -> Dict[str, float]:
        n = len(self.admission_waits)
        waits = self.admission_waits
        return {
            "jobs_admitted": float(self.n_admitted),
            "jobs_in_flight": float(len(self.jobs)),
            "jobs_waiting_dst": float(len(self.waiting)),
            "retries": float(self.n_retries),
            "requeues": float(self.n_requeues),
            "node_restores": float(self.n_restores),
            "link_flaps": float(self.n_flaps),
            "src_failed_jobs": float(self.n_src_failed),
            "admission_wait_mean_s": sum(waits) / n if n else 0.0,
            "link_busy_s": sum(l.busy_s for l in self.links.values()),
            "link_msgs": float(sum(l.n_msgs for l in self.links.values())),
            "link_bytes": float(sum(l.nbytes for l in self.links.values())),
            "state_segments": float(self.state_segments),
            "state_payload_bytes": float(self.state_bytes),
        }
