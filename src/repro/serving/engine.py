"""Real-compute P/D engines for the in-process mini-cluster.

PrefillEngine runs actual prefill batches and writes KV into a paged pool;
DecodeEngine runs continuous-batched paged decode (paged_attention kernel
for attention layers, dense recurrent states for mamba layers, dense
cross-attention KV for encoder-decoder archs). All assigned families are
supported: dense / moe / ssm / hybrid / vlm-backbone / audio (enc-dec).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.models.config import ATTN, ModelConfig
from repro.models.modeling import (
    _attn_proj_qkv, _ffn_sublayer, _merge_heads, _split_heads, lm_logits,
    rmsnorm, rope, forward_prefill, mamba_sublayer_step)
from repro.models.params import block_period, num_blocks
from repro.serving.kvcache import PagedKVPool

Tree = dict

# layer-streaming callback: (batch_index, attn_layer_index, k_layer
# (tokens, kv_dim), v_layer, network_depth_fraction). Invoked in network
# order as each attention layer's KV becomes available, so a transfer
# scheduler can ship layer i while layer i+1 is still prefilling
# (per-layer triggering, paper Fig. 10).
OnLayer = Callable[[int, int, jax.Array, jax.Array, float], None]


def _attn_layer_order(cfg: ModelConfig) -> List[Tuple[int, int]]:
    """(blk, sub) pairs of attention layers, in network order."""
    period = block_period(cfg)
    kinds = cfg.layer_kinds()
    return [(b, s) for b in range(num_blocks(cfg)) for s in range(period)
            if kinds[s] == ATTN]


def _mamba_layer_order(cfg: ModelConfig) -> List[Tuple[int, int]]:
    period = block_period(cfg)
    kinds = cfg.layer_kinds()
    return [(b, s) for b in range(num_blocks(cfg)) for s in range(period)
            if kinds[s] != ATTN]


def _slice_layer(params_sub: Tree, blk: int) -> Tree:
    return jax.tree.map(lambda x: x[blk], params_sub)


@dataclass
class PrefillOutput:
    first_token: int
    k: Optional[jax.Array]           # (attn_layers, tokens, kv_dim)
    v: Optional[jax.Array]
    mamba_state: Optional[Tree]      # per (blk,sub): conv/state tensors
    prompt_len: int
    cross: Optional[Tree] = None     # enc-dec: (blk,sub) -> (xk, xv)


class PrefillEngine:
    """Batched prefill on real params; emits per-request KV + states
    (+ cross-attention KV for encoder-decoder archs).

    ``run_suffix`` is the prefix-reuse fast path: given a gathered prefix
    KVCache it runs the forward pass over only the uncached suffix
    tokens. ``compute_tokens`` counts tokens actually pushed through the
    forward pass (the parity tests and benchmarks assert savings on it).
    """

    def __init__(self, cfg: ModelConfig, params: Tree):
        self.cfg = cfg
        self.params = params
        self._attn_order = _attn_layer_order(cfg)
        self._mamba_order = _mamba_layer_order(cfg)
        self.compute_tokens = 0      # tokens run through the forward pass
        self.reused_tokens = 0       # tokens served from a prefix hit
        self.prefix_prefills = 0     # suffix-only prefills executed

    def layer_fractions(self) -> List[float]:
        """Network-depth completion fraction of each attention layer, in
        network order: layer li's KV is producible once frac * T_prefill
        of the batch's compute has elapsed. Static per config — the
        transfer scheduler stamps segment ready-times with these."""
        period = block_period(self.cfg)
        total = num_blocks(self.cfg) * period
        return [(bk * period + sb + 1) / total for bk, sb in self._attn_order]

    def _emit_layers(self, on_layer: Optional[OnLayer], idx: int,
                     k: Optional[jax.Array], v: Optional[jax.Array]):
        """Yield one request's per-layer KV in network order."""
        if on_layer is None or k is None:
            return
        for li, frac in enumerate(self.layer_fractions()):
            on_layer(idx, li, k[li], v[li], frac)

    @property
    def supports_prefix_reuse(self) -> bool:
        """Prefix KV reuse needs a pure-attention stack: SSM/hybrid
        layers carry recurrent state that a KV prefix cannot restore, and
        attn-free stacks have no KV to reuse. Encoder-decoder is fine
        (the encoder reruns; only decoder self-attn KV is reused).
        Capacity-dispatch MoE is also gated off: its token dropping
        depends on the whole batch's T, so suffix-only prefill could
        silently change outputs — only the dropless "sorted" dispatch is
        prefix-transparent."""
        if not self._attn_order or self._mamba_order:
            return False
        m = self.cfg.moe
        if m is not None and m.dispatch == "capacity" \
                and any(self.cfg.moe_layer_mask()):
            return False
        return True

    def run(self, token_lists: Sequence[Sequence[int]],
            frames: Optional[Sequence] = None,
            on_layer: Optional[OnLayer] = None) -> List[PrefillOutput]:
        """Ragged batches are split into equal-length sub-batches: causal
        attention ignores right padding, but SSM/conv states would absorb
        padded tokens (observed as hybrid-arch divergence).

        ``on_layer`` enables the layer-streaming mode: each request's
        per-layer (k, v) is yielded in network order (see OnLayer) for
        per-layer-triggered transfer."""
        by_len: Dict[int, List[int]] = {}
        for i, t in enumerate(token_lists):
            by_len.setdefault(len(t), []).append(i)
        outs: List[Optional[PrefillOutput]] = [None] * len(token_lists)
        for ln, idxs in by_len.items():
            sub = self._run_equal(
                [token_lists[i] for i in idxs],
                [frames[i] for i in idxs] if frames is not None else None)
            for i, o in zip(idxs, sub):
                outs[i] = o
                self._emit_layers(on_layer, i, o.k, o.v)
        return outs  # type: ignore[return-value]

    def _run_equal(self, token_lists: Sequence[Sequence[int]],
                   frames: Optional[Sequence] = None
                   ) -> List[PrefillOutput]:
        cfg = self.cfg
        b = len(token_lists)
        lens = [len(t) for t in token_lists]
        s = max(lens)
        toks = np.zeros((b, s), np.int32)
        for i, t in enumerate(token_lists):
            toks[i, :len(t)] = t
        batch = {"tokens": jnp.asarray(toks)}
        self.compute_tokens += b * s
        if cfg.is_encoder_decoder:
            assert frames is not None, "enc-dec prefill needs frames"
            batch["frames"] = jnp.stack([jnp.asarray(f) for f in frames])
        first, cache = forward_prefill(
            cfg, self.params, batch,
            last_index=jnp.asarray([ln - 1 for ln in lens]))
        outs: List[PrefillOutput] = []
        layers = cache["layers"]
        for i, ln in enumerate(lens):
            if self._attn_order:
                k = jnp.stack([layers[f"sub{sb}"]["k"][bk, i, :ln]
                               for bk, sb in self._attn_order])
                v = jnp.stack([layers[f"sub{sb}"]["v"][bk, i, :ln]
                               for bk, sb in self._attn_order])
            else:
                k = v = None
            mstate: Tree = {}
            for bk, sb in self._mamba_order:
                c = layers[f"sub{sb}"]
                mstate[(bk, sb)] = {
                    "conv_x": c["conv_x"][bk, i],
                    "conv_b": c["conv_b"][bk, i],
                    "conv_c": c["conv_c"][bk, i],
                    "state": c["state"][bk, i],
                }
            cross: Optional[Tree] = None
            if cfg.is_encoder_decoder:
                cross = {}
                from repro.models.params import block_period, num_blocks
                for bk in range(num_blocks(cfg)):
                    for sb in range(block_period(cfg)):
                        c = layers[f"sub{sb}"]
                        cross[(bk, sb)] = (c["xk"][bk, i], c["xv"][bk, i])
            outs.append(PrefillOutput(int(first[i]), k, v, mstate, ln,
                                      cross))
        return outs

    def run_suffix(self, suffix_tokens: Sequence[int], prefix_kv: jax.Array,
                   frames: Optional[object] = None,
                   on_layer: Optional[OnLayer] = None) -> PrefillOutput:
        """Suffix-only prefill after a prefix hit.

        ``prefix_kv``: (attn_layers, plen, 2*kv_dim) — the cached prefix
        KVCache gathered from the paged pool (kernels.kv_gather), K and V
        packed along the last axis exactly as the pool stores them. Runs
        the forward pass over only ``suffix_tokens`` with every attention
        sublayer attending over prefix ++ suffix; returns a PrefillOutput
        whose k/v cover the FULL prompt (prefix stitched back on) so the
        transfer/decode path downstream is unchanged.
        """
        cfg = self.cfg
        assert self.supports_prefix_reuse, cfg.name
        s = len(suffix_tokens)
        assert s >= 1, "prefix hit must leave at least one suffix token"
        plen = int(prefix_kv.shape[1])
        kvd = cfg.kv_dim
        k_pre, v_pre = prefix_kv[..., :kvd], prefix_kv[..., kvd:]
        period = block_period(cfg)
        nblk = num_blocks(cfg)
        attn_idx = {pair: li for li, pair in enumerate(self._attn_order)}
        prefix: Tree = {}
        for sb in range(period):
            ks = jnp.stack([k_pre[attn_idx[(bk, sb)]] for bk in range(nblk)])
            vs = jnp.stack([v_pre[attn_idx[(bk, sb)]] for bk in range(nblk)])
            # (num_blocks, b=1, plen, kv_dim), scanned alongside params
            prefix[f"sub{sb}"] = {"k": ks[:, None], "v": vs[:, None]}
        batch = {"tokens": jnp.asarray([list(suffix_tokens)], jnp.int32)}
        if cfg.is_encoder_decoder:
            assert frames is not None, "enc-dec prefill needs frames"
            batch["frames"] = jnp.asarray(frames)[None]
        first, cache = forward_prefill(
            cfg, self.params, batch,
            last_index=jnp.asarray([s - 1]), prefix=prefix, prefix_len=plen)
        self.compute_tokens += s
        self.reused_tokens += plen
        self.prefix_prefills += 1
        layers = cache["layers"]
        k_suf = jnp.stack([layers[f"sub{sb}"]["k"][bk, 0, :s]
                           for bk, sb in self._attn_order])
        v_suf = jnp.stack([layers[f"sub{sb}"]["v"][bk, 0, :s]
                           for bk, sb in self._attn_order])
        k = jnp.concatenate([k_pre.astype(k_suf.dtype), k_suf], axis=1)
        v = jnp.concatenate([v_pre.astype(v_suf.dtype), v_suf], axis=1)
        cross: Optional[Tree] = None
        if cfg.is_encoder_decoder:
            cross = {}
            for bk in range(nblk):
                for sb in range(period):
                    c = layers[f"sub{sb}"]
                    cross[(bk, sb)] = (c["xk"][bk, 0], c["xv"][bk, 0])
        out = PrefillOutput(int(first[0]), k, v, {}, plen + s, cross)
        # stream the FULL prompt's layers (prefix stitched back on): the
        # receiver's layout is identical to a cold prefill's
        self._emit_layers(on_layer, 0, k, v)
        return out


class DecodeEngine:
    """Continuous-batched paged decode over a PagedKVPool."""

    def __init__(self, cfg: ModelConfig, params: Tree, pool: PagedKVPool,
                 *, max_slots: int = 8):
        self.cfg = cfg
        self.params = params
        self.pool = pool
        self.max_slots = max_slots
        self._attn_order = _attn_layer_order(cfg)
        self._mamba_order = _mamba_layer_order(cfg)
        # slot state
        self.rid = [None] * max_slots
        self.pos = np.zeros(max_slots, np.int64)      # tokens so far
        self.last_tok = np.zeros(max_slots, np.int32)
        s_cfg = cfg.ssm
        self._cross_slots: Dict[Tuple[int, int], Tuple] = {}
        if cfg.is_encoder_decoder:
            from repro.models.params import block_period, num_blocks
            for bk in range(num_blocks(cfg)):
                for sb in range(block_period(cfg)):
                    self._cross_slots[(bk, sb)] = (
                        jnp.zeros((max_slots, cfg.encoder_seq, cfg.kv_dim)),
                        jnp.zeros((max_slots, cfg.encoder_seq, cfg.kv_dim)))
        self._mamba_slots: Dict[Tuple[int, int], Tree] = {}
        if self._mamba_order:
            d_in = s_cfg.expand * cfg.d_model
            gn = s_cfg.n_groups * s_cfg.d_state
            nh = d_in // s_cfg.head_dim
            kk = s_cfg.conv_kernel
            for key in self._mamba_order:
                self._mamba_slots[key] = {
                    "conv_x": jnp.zeros((max_slots, d_in, kk - 1)),
                    "conv_b": jnp.zeros((max_slots, gn, kk - 1)),
                    "conv_c": jnp.zeros((max_slots, gn, kk - 1)),
                    "state": jnp.zeros((max_slots, nh, s_cfg.d_state,
                                        s_cfg.head_dim)),
                }

    # ------------------------------------------------------------- slots
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.rid) if r is None]

    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.rid) if r is not None]

    def admit(self, rid: int, out: PrefillOutput, blocks: Sequence[int],
              slot: Optional[int] = None) -> int:
        """Attach a transferred request to a free slot. The KV for its
        prompt must already be in `self.pool` under `blocks`."""
        if slot is None:
            free = self.free_slots()
            if not free:
                raise RuntimeError("no free decode slot")
            slot = free[0]
        self.rid[slot] = rid
        self.pos[slot] = out.prompt_len
        self.last_tok[slot] = out.first_token
        for key, st in (out.mamba_state or {}).items():
            buf = self._mamba_slots[key]
            for k2 in buf:
                buf[k2] = buf[k2].at[slot].set(st[k2].astype(buf[k2].dtype))
        for key, (xk, xv) in (out.cross or {}).items():
            bk_, bv_ = self._cross_slots[key]
            self._cross_slots[key] = (
                bk_.at[slot].set(xk.astype(bk_.dtype)),
                bv_.at[slot].set(xv.astype(bv_.dtype)))
        return slot

    def evict(self, slot: int):
        self.rid[slot] = None

    # -------------------------------------------------------------- step
    def step(self) -> Dict[int, int]:
        """One decode iteration over all active slots.
        Returns {slot: next_token}."""
        cfg = self.cfg
        act = self.active_slots()
        if not act:
            return {}
        act_arr = np.asarray(act)
        toks = jnp.asarray(self.last_tok[act_arr])
        pos = jnp.asarray(self.pos[act_arr])          # tokens so far
        h = self.params["embed"][toks].astype(jnp.float32)
        period = block_period(cfg)
        kinds = cfg.layer_kinds()
        moe_mask = cfg.moe_layer_mask()
        attn_idx = {pair: i for i, pair in enumerate(self._attn_order)}
        # block tables sized to the largest allocation among active slots
        nblocks = max(len(self.pool.owned(self.rid[s])) for s in act)
        bt = jnp.asarray(self.pool.block_tables(
            [self.rid[s] for s in act], nblocks))
        lens = pos + 1                                 # incl. current token

        for bk in range(num_blocks(cfg)):
            for sb in range(period):
                p = _slice_layer(self.params["blocks"][f"sub{sb}"], bk)
                if kinds[sb] == ATTN:
                    li = attn_idx[(bk, sb)]
                    x = rmsnorm(h, p["norm"], cfg.norm_eps)
                    q, k, v = _attn_proj_qkv(p, x[:, None, :], cfg)
                    q4 = _split_heads(q[:, 0], cfg.num_heads)
                    k4 = _split_heads(k[:, 0], cfg.num_kv_heads)
                    q4 = rope(q4, pos, cfg.rope_theta)
                    k4 = rope(k4, pos, cfg.rope_theta)
                    kf, vf = _merge_heads(k4), v[:, 0]
                    # write the token into the pool at (block, offset)
                    blk_ids, offs = [], []
                    for s_i in act:
                        bl = self.pool.owned(self.rid[s_i])
                        t = int(self.pos[s_i])
                        blk_ids.append(bl[t // self.pool.block_size])
                        offs.append(t % self.pool.block_size)
                    kv_tok = jnp.concatenate([kf, vf], -1).astype(
                        self.pool.dtype)
                    st = self.pool.storage.at[
                        li, jnp.asarray(blk_ids), jnp.asarray(offs)
                    ].set(kv_tok)
                    self.pool.storage = st
                    o = ops.paged_attention(
                        q4.astype(self.pool.dtype),
                        self.pool.storage[li], bt,
                        lens.astype(jnp.int32))
                    h = h + _merge_heads(o).astype(h.dtype) @ p["wo"]
                else:
                    buf = self._mamba_slots[(bk, sb)]
                    cin = {k2: v2[act_arr] for k2, v2 in buf.items()}
                    h, nc = mamba_sublayer_step(p, h, cin, cfg)
                    for k2 in buf:
                        buf[k2] = buf[k2].at[act_arr].set(
                            nc[k2].astype(buf[k2].dtype))
                if cfg.is_encoder_decoder:
                    from repro.models.modeling import attention_decode
                    xk, xv = self._cross_slots[(bk, sb)]
                    x = rmsnorm(h, p["norm_x"], cfg.norm_eps)
                    q4 = _split_heads(x @ p["wqx"], cfg.num_heads)
                    o = attention_decode(
                        q4.astype(jnp.float32), xk[act_arr], xv[act_arr],
                        cfg.num_kv_heads,
                        jnp.asarray(cfg.encoder_seq), window=None)
                    h = h + _merge_heads(o).astype(h.dtype) @ p["wox"]
                h2, _ = _ffn_sublayer(p, h[:, None, :], cfg, moe_mask[sb])
                h = h2[:, 0]
        h = rmsnorm(h, self.params["final_norm"], cfg.norm_eps)
        logits = lm_logits(cfg, self.params, h)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        out: Dict[int, int] = {}
        for j, s_i in enumerate(act):
            self.pos[s_i] += 1
            self.last_tok[s_i] = nxt[j]
            out[s_i] = int(nxt[j])
        return out
