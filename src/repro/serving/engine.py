"""Real-compute P/D engines for the in-process mini-cluster.

PrefillEngine runs actual prefill batches and writes KV into a paged pool;
DecodeEngine runs continuous-batched paged decode (paged_attention kernel
for attention layers, dense recurrent states for mamba layers, dense
cross-attention KV for encoder-decoder archs). All assigned families are
supported: dense / moe / ssm / hybrid / vlm-backbone / audio (enc-dec).

Hot-loop shape discipline (the §2.2.3 perf model only holds if the
engines run as fast as the hardware allows):

  * prefill batches are padded to power-of-two length BUCKETS for EVERY
    family and run through one shared jitted forward, so the compile
    count is O(num_buckets), not O(distinct prompt lengths). Padding is
    exact by the model's pad-invariance contract (masked attention
    queries, zero-dt SSD recurrence, null-slot window-local MoE
    capacity — see models.modeling.forward_seq); suffix-only
    (prefix-reuse) prefills additionally bucket the PREFIX KV length,
    so warm admissions share one program per (prefix bucket, suffix
    bucket) pair. (The ``REPRO_PREFILL=exact`` env hatch was retired
    after the bucketed default survived three releases;
    ``bucket_prefill=False`` remains a constructor arg for
    measurement);
  * the decode iteration is ONE jitted, buffer-donated device program
    (``models.modeling.decode_step_jit``) over fixed-shape slot state —
    padded (max_slots,) token/position/mask arrays, a power-of-two
    bucketed block table, and block-stacked mamba/cross slot buffers —
    with exactly one device->host transfer per step (the argmax) and no
    per-layer pool copies (the paged pool is donated into the step).
    ``fused=False`` (constructor arg) keeps the legacy eager per-layer
    loop as the measured benchmark baseline; both paths are
    token-identical by test. (The ``REPRO_DECODE=eager`` env hatch was
    retired after the fused path survived three releases as default.)
"""
from __future__ import annotations

import math
import os
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.models.caches import decode_slot_state
from repro.models.config import ATTN, ModelConfig
from repro.models.modeling import (
    _attn_proj_qkv, _ffn_sublayer, _merge_heads, _split_heads,
    decode_step_jit, forward_prefill, lm_logits, mamba_sublayer_step,
    rmsnorm, rope, spec_decode_step_jit)
from repro.models.params import block_period, num_blocks
from repro.serving.kvcache import PagedKVPool
from repro.serving.speculative import SpecConfig

Tree = dict

# layer-streaming callback: (batch_index, attn_layer_index, k_layer
# (tokens, kv_dim), v_layer, network_depth_fraction). Invoked in network
# order as each attention layer's KV becomes available, so a transfer
# scheduler can ship layer i while layer i+1 is still prefilling
# (per-layer triggering, paper Fig. 10).
OnLayer = Callable[[int, int, jax.Array, jax.Array, float], None]

# smallest prefill length bucket; buckets double up to cfg.max_seq_len
PREFILL_BUCKET_MIN = 16

# One shared jitted prefill across every engine instance: the cache is
# keyed on (cfg, shapes), so N serving nodes of the same arch compile
# each length bucket once, not once per node. prefix_len is a TRACED
# operand (the prefix KV is padded to a static bucket), so warm
# prefix-reuse admissions retrace per (prefix bucket, suffix bucket) —
# never per distinct prefix length.
_jit_forward_prefill = jax.jit(
    forward_prefill, static_argnames=("cfg", "window", "snap_stride"))


def prefill_compile_count() -> int:
    """Live compilation-cache entries of the shared jitted prefill (the
    retrace-count guard asserts deltas on this under ragged traffic)."""
    return _jit_forward_prefill._cache_size()


def _attn_layer_order(cfg: ModelConfig) -> List[Tuple[int, int]]:
    """(blk, sub) pairs of attention layers, in network order."""
    period = block_period(cfg)
    kinds = cfg.layer_kinds()
    return [(b, s) for b in range(num_blocks(cfg)) for s in range(period)
            if kinds[s] == ATTN]


def _mamba_layer_order(cfg: ModelConfig) -> List[Tuple[int, int]]:
    period = block_period(cfg)
    kinds = cfg.layer_kinds()
    return [(b, s) for b in range(num_blocks(cfg)) for s in range(period)
            if kinds[s] != ATTN]


def _slice_layer(params_sub: Tree, blk: int) -> Tree:
    return jax.tree.map(lambda x: x[blk], params_sub)


@dataclass
class PrefillOutput:
    first_token: int
    k: Optional[jax.Array]           # (attn_layers, tokens, kv_dim)
    v: Optional[jax.Array]
    mamba_state: Optional[Tree]      # per (blk,sub): conv/state tensors
    prompt_len: int
    cross: Optional[Tree] = None     # enc-dec: (blk,sub) -> (xk, xv)
    # recurrent-state snapshots for the prefix store: absolute token
    # boundary -> per-(blk,sub) {"conv_x","conv_b","conv_c","state"}
    snapshots: Optional[Dict[int, Tree]] = None


class PrefillEngine:
    """Batched prefill on real params; emits per-request KV + states
    (+ cross-attention KV for encoder-decoder archs).

    ``run_suffix`` is the prefix-reuse fast path: given a gathered prefix
    KVCache it runs the forward pass over only the uncached suffix
    tokens. ``compute_tokens`` counts real prompt tokens pushed through
    the forward pass — bucket padding is tracked separately in
    ``padded_tokens`` (the parity tests and benchmarks assert savings on
    the exact counter). ``prefill_batches`` / ``bucket_hits`` ledger how
    often a batch landed on an already-seen shape bucket (a compile-
    cache hit for this engine) — the frontend's compile-stall telemetry.
    """

    def __init__(self, cfg: ModelConfig, params: Tree, *,
                 bucket_prefill: Optional[bool] = None,
                 jit_prefill: Optional[bool] = None):
        self.cfg = cfg
        self.params = params
        self._attn_order = _attn_layer_order(cfg)
        self._mamba_order = _mamba_layer_order(cfg)
        # network-depth completion fraction per attention layer — static
        # per config, computed ONCE (the transfer scheduler reads it per
        # admitted request)
        period = block_period(cfg)
        total = num_blocks(cfg) * period
        self._layer_fractions: Tuple[float, ...] = tuple(
            (bk * period + sb + 1) / total for bk, sb in self._attn_order)
        if bucket_prefill is None:
            # bucketed is THE path (the REPRO_PREFILL=exact env hatch
            # was retired after the bucketed default survived three
            # releases); the constructor arg remains for measurement
            bucket_prefill = True
        if jit_prefill is None:
            jit_prefill = os.environ.get("REPRO_PREFILL_JIT", "1") != "0"
        # bucketing serves EVERY family: the forward is pad-invariant by
        # contract (there is no per-arch gate anymore)
        self.bucket_prefill = bool(bucket_prefill)
        self.jit_prefill = bool(jit_prefill)
        self.compute_tokens = 0      # real prompt tokens through the fwd
        self.padded_tokens = 0       # bucket-padding tokens on top
        self.reused_tokens = 0       # tokens served from a prefix hit
        self.prefix_prefills = 0     # suffix-only prefills executed
        self.state_restores = 0      # warm runs seeded from a snapshot
        self.prefill_batches = 0     # jitted batch launches
        self.bucket_hits = 0         # launches on an already-seen shape
        self.chunked_prefills = 0    # prompts completed via iter_chunks
        self.chunked_chunks = 0      # individual chunk launches
        self._shapes_seen: set = set()

    def _prefill(self, batch: Tree, *, last_index: jax.Array,
                 prefix: Optional[Tree] = None, prefix_len: int = 0,
                 ssm_init: Optional[Tree] = None, snap_stride: int = 0):
        if self.jit_prefill:
            return _jit_forward_prefill(self.cfg, self.params, batch,
                                        last_index=last_index,
                                        prefix=prefix,
                                        prefix_len=prefix_len,
                                        ssm_init=ssm_init,
                                        snap_stride=snap_stride)
        return forward_prefill(self.cfg, self.params, batch,
                               last_index=last_index, prefix=prefix,
                               prefix_len=prefix_len, ssm_init=ssm_init,
                               snap_stride=snap_stride)

    def layer_fractions(self) -> Tuple[float, ...]:
        """Network-depth completion fraction of each attention layer, in
        network order: layer li's KV is producible once frac * T_prefill
        of the batch's compute has elapsed. Static per config — the
        transfer scheduler stamps segment ready-times with these."""
        return self._layer_fractions

    def _emit_layers(self, on_layer: Optional[OnLayer], idx: int,
                     k: Optional[jax.Array], v: Optional[jax.Array]):
        """Yield one request's per-layer KV in network order."""
        if on_layer is None or k is None:
            return
        for li, frac in enumerate(self._layer_fractions):
            on_layer(idx, li, k[li], v[li], frac)

    @property
    def supports_prefix_reuse(self) -> bool:
        """Every family reuses prefixes now. Pure-attention stacks reuse
        the KV prefix alone; SSM/hybrid stacks additionally restore a
        recurrent-state snapshot cached at the reuse boundary (see
        ``requires_state_restore`` — the pool stores snapshots in
        lockstep with the KV blocks). Encoder-decoder is fine (the
        encoder reruns; only decoder self-attn KV is reused).
        Capacity-dispatch MoE is prefix-transparent since capacity went
        window-local and row-length-independent — its hits only need the
        prefix length aligned to the capacity window (``prefix_align``,
        enforced by the pool's aligned acquire).

        SSM/hybrid reuse is gated on the BUCKETED prefill path: the
        bit-identical state contract needs geometry control — a
        tiny exact-length suffix run (fewer rows than a vector tile)
        fuses/vectorizes differently and wobbles the SSD state by ulps,
        and padding it is not an option for hybrids because the warm
        attention must occupy exactly the cold run's padded key
        geometry. Under ``bucket_prefill=False`` these families simply
        serve cold, as they did before snapshots existed."""
        if self._mamba_order and not self.bucket_prefill:
            return False
        return bool(self._attn_order) or bool(self._mamba_order)

    @property
    def requires_state_restore(self) -> bool:
        """SSM/hybrid stacks: a warm hit must restore a recurrent-state
        snapshot (conv tails + SSD state) alongside any prefix KV — the
        pool only reports hits at boundaries that hold one."""
        return bool(self._mamba_order)

    @property
    def prefix_align(self) -> int:
        """Token alignment a reused prefix must satisfy. Capacity MoE
        counts expert slots in fixed windows of cfg.moe.capacity_window
        tokens: a prefix cut at a window boundary guarantees the suffix
        run sees exactly the windows a full run would give its suffix
        tokens (no capacity competition across the reuse boundary).
        Mamba layers need the cut on an SSD chunk boundary: the per-chunk
        scan carry is bitwise the state of a run truncated there, and a
        chunk-aligned restore keeps the suffix chunk partition identical
        to the cold run's. Hybrid stacks take the lcm."""
        a = 1
        m = self.cfg.moe
        if m is not None and m.dispatch == "capacity" \
                and any(self.cfg.moe_layer_mask()):
            a = m.capacity_window
        if self._mamba_order:
            a = math.lcm(a, self.cfg.ssm_cfg.chunk)
        return a

    def _bucket_len(self, n: int) -> int:
        b = PREFILL_BUCKET_MIN
        while b < n:
            b *= 2
        return min(b, max(self.cfg.max_seq_len, n))

    def _count_launch(self, shape_key: Tuple) -> None:
        self.prefill_batches += 1
        if shape_key in self._shapes_seen:
            self.bucket_hits += 1
        else:
            self._shapes_seen.add(shape_key)

    def run(self, token_lists: Sequence[Sequence[int]],
            frames: Optional[Sequence] = None,
            on_layer: Optional[OnLayer] = None,
            snap_stride: int = 0) -> List[PrefillOutput]:
        """Ragged batches are grouped into padded power-of-two length
        buckets for EVERY family (retrace count becomes O(num_buckets)
        under tidal ragged traffic): right padding is exact by the
        model's pad-invariance contract — causal attention masks padded
        queries, the SSD recurrence skips zero-dt pad tokens bit-exactly,
        and window-local capacity MoE routes pads to a null slot.
        (``bucket_prefill=False`` falls back to equal-length
        sub-batches for measurement.)

        ``on_layer`` enables the layer-streaming mode: each request's
        per-layer (k, v) is yielded in network order (see OnLayer) for
        per-layer-triggered transfer.

        ``snap_stride`` > 0 (static; lcm of the pool block size and the
        SSD chunk, supplied by the serving node) makes mamba sublayers
        emit recurrent-state snapshots at stride boundaries; each
        output's ``snapshots`` maps boundary -> per-layer state for the
        prefix store."""
        by_len: Dict[int, List[int]] = {}
        for i, t in enumerate(token_lists):
            key = self._bucket_len(len(t)) if self.bucket_prefill else len(t)
            by_len.setdefault(key, []).append(i)
        outs: List[Optional[PrefillOutput]] = [None] * len(token_lists)
        for ln, idxs in by_len.items():
            sub = self._run_equal(
                [token_lists[i] for i in idxs],
                [frames[i] for i in idxs] if frames is not None else None,
                pad_to=ln if self.bucket_prefill else None,
                snap_stride=snap_stride)
            for i, o in zip(idxs, sub):
                outs[i] = o
                self._emit_layers(on_layer, i, o.k, o.v)
        return outs  # type: ignore[return-value]

    def _run_equal(self, token_lists: Sequence[Sequence[int]],
                   frames: Optional[Sequence] = None,
                   pad_to: Optional[int] = None,
                   snap_stride: int = 0
                   ) -> List[PrefillOutput]:
        cfg = self.cfg
        if not self._mamba_order:
            snap_stride = 0          # snapshots are an SSM-only artifact
        b = len(token_lists)
        lens = [len(t) for t in token_lists]
        s = pad_to if pad_to is not None else max(lens)
        assert s >= max(lens), (s, lens)
        toks = np.zeros((b, s), np.int32)
        for i, t in enumerate(token_lists):
            toks[i, :len(t)] = t
        batch = {"tokens": jnp.asarray(toks)}
        self.compute_tokens += sum(lens)
        self.padded_tokens += b * s - sum(lens)
        self._count_launch((b, s, snap_stride))
        if cfg.is_encoder_decoder:
            assert frames is not None, "enc-dec prefill needs frames"
            batch["frames"] = jnp.stack([jnp.asarray(f) for f in frames])
        first, cache = self._prefill(
            batch, last_index=jnp.asarray([ln - 1 for ln in lens]),
            snap_stride=snap_stride)
        outs: List[PrefillOutput] = []
        layers = cache["layers"]
        for i, ln in enumerate(lens):
            if self._attn_order:
                k = jnp.stack([layers[f"sub{sb}"]["k"][bk, i, :ln]
                               for bk, sb in self._attn_order])
                v = jnp.stack([layers[f"sub{sb}"]["v"][bk, i, :ln]
                               for bk, sb in self._attn_order])
            else:
                k = v = None
            mstate: Tree = {}
            for bk, sb in self._mamba_order:
                c = layers[f"sub{sb}"]
                mstate[(bk, sb)] = {
                    "conv_x": c["conv_x"][bk, i],
                    "conv_b": c["conv_b"][bk, i],
                    "conv_c": c["conv_c"][bk, i],
                    "state": c["state"][bk, i],
                }
            cross: Optional[Tree] = None
            if cfg.is_encoder_decoder:
                cross = {}
                for bk in range(num_blocks(cfg)):
                    for sb in range(block_period(cfg)):
                        c = layers[f"sub{sb}"]
                        cross[(bk, sb)] = (c["xk"][bk, i], c["xv"][bk, i])
            snaps = self._extract_snapshots(layers, i, lens[i],
                                            snap_stride, s, base=0)
            outs.append(PrefillOutput(int(first[i]), k, v, mstate, ln,
                                      cross, snaps))
        return outs

    def _extract_snapshots(self, layers: Tree, row: int, valid: int,
                           snap_stride: int, s_pad: int, base: int
                           ) -> Optional[Dict[int, Tree]]:
        """Per-request boundary snapshots from the stacked prefill cache:
        {base + j*stride: {(blk,sub): conv tails + SSD state}} for every
        stride boundary inside the row's VALID tokens (boundaries past
        valid_len hold frozen state but pad-garbage conv rows — never
        stored). ``base`` offsets boundaries to absolute prompt
        positions for suffix-only runs."""
        if not snap_stride or not self._mamba_order:
            return None
        snaps: Dict[int, Tree] = {}
        for j in range(1, s_pad // snap_stride + 1):
            t = j * snap_stride
            if t > valid:
                break
            entry: Tree = {}
            for bk, sb in self._mamba_order:
                c = layers[f"sub{sb}"]
                entry[(bk, sb)] = {
                    "conv_x": c["snap_conv_x"][bk, j - 1, row],
                    "conv_b": c["snap_conv_b"][bk, j - 1, row],
                    "conv_c": c["snap_conv_c"][bk, j - 1, row],
                    "state": c["snap_state"][bk, j - 1, row],
                }
            snaps[base + t] = entry
        return snaps

    def run_suffix(self, suffix_tokens: Sequence[int],
                   prefix_kv: Optional[jax.Array] = None,
                   frames: Optional[object] = None,
                   on_layer: Optional[OnLayer] = None, *,
                   state: Optional[Tree] = None,
                   prefix_len: Optional[int] = None,
                   snap_stride: int = 0) -> PrefillOutput:
        """Suffix-only prefill after a prefix hit.

        ``prefix_kv``: (attn_layers, plen, 2*kv_dim) — the cached prefix
        KVCache gathered from the paged pool (kernels.kv_gather), K and V
        packed along the last axis exactly as the pool stores them; None
        for attention-free stacks (whose prefix lives entirely in
        ``state``). Runs the forward pass over only ``suffix_tokens``
        (right-padded to a length bucket — pad rows attend to nothing
        and are sliced off) with every attention sublayer attending over
        prefix ++ suffix; returns a PrefillOutput whose k/v cover the
        FULL prompt (prefix stitched back on) so the transfer/decode
        path downstream is unchanged. The prefix KV is right-padded to
        its own power-of-two bucket with the real length passed as a
        TRACED scalar (padded prefix keys are masked from every
        softmax), so warm admissions retrace per (prefix bucket, suffix
        bucket) — O(num_buckets^2) programs cluster-wide — never per
        distinct prefix length.

        ``state`` is the boundary snapshot for SSM/hybrid stacks — per
        (blk,sub) {"conv_x","conv_b","conv_c","state"} cached by the
        pool at the reuse boundary — seeding each mamba sublayer's conv
        windows and SSD scan so the suffix run continues the recurrence
        bitwise (the returned ``mamba_state`` is the RESTORED state
        advanced over the suffix, ready for decode hand-off / transfer).
        ``prefix_len`` is required when ``prefix_kv`` is None.
        ``snap_stride`` > 0 additionally emits new snapshots over the
        suffix, reported at ABSOLUTE boundaries in ``out.snapshots``.
        """
        cfg = self.cfg
        assert self.supports_prefix_reuse, cfg.name
        if self.requires_state_restore:
            assert state is not None, \
                f"{cfg.name}: SSM warm hit needs a state snapshot"
        s = len(suffix_tokens)
        assert s >= 1, "prefix hit must leave at least one suffix token"
        plen = int(prefix_kv.shape[1]) if prefix_kv is not None \
            else int(prefix_len)
        if prefix_kv is not None and self._mamba_order and \
                self.bucket_prefill:
            # hybrid (attn + SSM) warm runs carry a BITWISE state-parity
            # contract: XLA's key-axis reduction tiling depends on the
            # padded length, so the warm softmax/PV matmul only
            # reproduces the cold run bit-for-bit when prefix ++ suffix
            # keys occupy exactly the geometry the cold run padded to —
            # prefix at its true (aligned) length, suffix padded so the
            # total lands on the cold bucket of the full prompt.
            s_pad = self._bucket_len(plen + s) - plen
        else:
            s_pad = self._bucket_len(s) if self.bucket_prefill else s
        assert prefix_len is None or int(prefix_len) == plen
        # capacity-MoE / SSD-chunk prefix hits must land on aligned
        # boundaries (the pool's aligned acquire guarantees this; a
        # misaligned prefix would shift the suffix's capacity windows
        # or de-align the suffix SSD chunk partition)
        assert plen % self.prefix_align == 0, (plen, self.prefix_align)
        period = block_period(cfg)
        nblk = num_blocks(cfg)
        prefix: Optional[Tree] = None
        k_pre = v_pre = None
        p_pad = 0
        if prefix_kv is not None:
            # hybrid: prefix stays at its exact aligned length (see the
            # s_pad choice above); attn-only keeps the O(buckets^2)
            # prefix-bucket scheme
            p_pad = plen if self._mamba_order else (
                self._bucket_len(plen) if self.bucket_prefill else plen)
            if p_pad != plen:
                prefix_kv = jnp.pad(prefix_kv,
                                    ((0, 0), (0, p_pad - plen), (0, 0)))
            kvd = cfg.kv_dim
            k_pre, v_pre = prefix_kv[..., :kvd], prefix_kv[..., kvd:]
            attn_idx = {pair: li
                        for li, pair in enumerate(self._attn_order)}
            prefix = {}
            for sb in range(period):
                if (0, sb) not in attn_idx:
                    prefix[f"sub{sb}"] = {}   # mamba sub: state, not KV
                    continue
                ks = jnp.stack([k_pre[attn_idx[(bk, sb)]]
                                for bk in range(nblk)])
                vs = jnp.stack([v_pre[attn_idx[(bk, sb)]]
                                for bk in range(nblk)])
                # (num_blocks, b=1, p_pad, kv_dim), scanned with params
                prefix[f"sub{sb}"] = {"k": ks[:, None], "v": vs[:, None]}
        ssm_init: Optional[Tree] = None
        if state is not None:
            mamba_subs = {sb for _, sb in self._mamba_order}
            ssm_init = {}
            for sb in range(period):
                if sb not in mamba_subs:
                    ssm_init[f"sub{sb}"] = {}
                    continue
                # stack snapshot leaves over blocks, batch dim 1 — exact
                # dtypes preserved (restore must be bitwise)
                ssm_init[f"sub{sb}"] = {
                    k2: jnp.stack([jnp.asarray(state[(bk, sb)][k2])[None]
                                   for bk in range(nblk)])
                    for k2 in ("conv_x", "conv_b", "conv_c", "state")}
        toks = list(suffix_tokens) + [0] * (s_pad - s)
        batch = {"tokens": jnp.asarray([toks], jnp.int32)}
        if cfg.is_encoder_decoder:
            assert frames is not None, "enc-dec prefill needs frames"
            batch["frames"] = jnp.asarray(frames)[None]
        first, cache = self._prefill(
            batch, last_index=jnp.asarray([s - 1]), prefix=prefix,
            prefix_len=jnp.asarray(plen, jnp.int32), ssm_init=ssm_init,
            snap_stride=snap_stride if self._mamba_order else 0)
        self.compute_tokens += s
        self.padded_tokens += (s_pad - s) + (p_pad - plen if p_pad else 0)
        self.reused_tokens += plen
        self.prefix_prefills += 1
        if state is not None:
            self.state_restores += 1
        self._count_launch(("suffix", p_pad, s_pad, snap_stride))
        layers = cache["layers"]
        k = v = None
        if self._attn_order:
            k_suf = jnp.stack([layers[f"sub{sb}"]["k"][bk, 0, :s]
                               for bk, sb in self._attn_order])
            v_suf = jnp.stack([layers[f"sub{sb}"]["v"][bk, 0, :s]
                               for bk, sb in self._attn_order])
            # stitch with the REAL prefix rows only (bucket pads sliced
            # off): no KV row past the ledgered compute/reused tokens
            # survives
            k = jnp.concatenate([k_pre[:, :plen].astype(k_suf.dtype),
                                 k_suf], axis=1)
            v = jnp.concatenate([v_pre[:, :plen].astype(v_suf.dtype),
                                 v_suf], axis=1)
        mstate: Tree = {}
        for bk, sb in self._mamba_order:
            c = layers[f"sub{sb}"]
            mstate[(bk, sb)] = {
                "conv_x": c["conv_x"][bk, 0],
                "conv_b": c["conv_b"][bk, 0],
                "conv_c": c["conv_c"][bk, 0],
                "state": c["state"][bk, 0],
            }
        cross: Optional[Tree] = None
        if cfg.is_encoder_decoder:
            cross = {}
            for bk in range(nblk):
                for sb in range(period):
                    c = layers[f"sub{sb}"]
                    cross[(bk, sb)] = (c["xk"][bk, 0], c["xv"][bk, 0])
        snaps = self._extract_snapshots(
            layers, 0, s, snap_stride if self._mamba_order else 0,
            s_pad, base=plen)
        out = PrefillOutput(int(first[0]), k, v, mstate, plen + s, cross,
                            snaps)
        # stream the FULL prompt's layers (prefix stitched back on): the
        # receiver's layout is identical to a cold prefill's
        self._emit_layers(on_layer, 0, k, v)
        return out

    # ------------------------------------------------- chunked prefill
    def chunk_bounds(self, n: int, chunk_tokens: int) -> List[int]:
        """Interior cut points for a chunked prefill of an ``n``-token
        prompt. Cuts land on ``prefix_align`` boundaries (the same
        contract the prefix store's aligned acquire enforces) and the
        final chunk always keeps >= 1 token, so each continuation is a
        legal ``run_suffix``."""
        align = max(self.prefix_align, 1)
        step = max(align, (int(chunk_tokens) // align) * align)
        return list(range(step, n, step))

    def iter_chunks(self, tokens: Sequence[int], *, chunk_tokens: int,
                    frames: Optional[object] = None):
        """DynaServe-style chunked prefill: run the prompt as a cold
        first chunk followed by ``run_suffix`` continuations, threading
        the stitched KV and (for SSM/hybrid stacks) the advanced
        recurrent state across chunks. Yields ``(n_chunk_tokens, out)``
        after each chunk so an event-driven caller can interleave other
        work (decode steps) between chunks; the final yield's output
        covers the full prompt and is token-identical to
        ``run([tokens])[0]`` — it is the identical warm-continuation
        machinery the prefix store's bitwise contracts already pin."""
        assert self.supports_prefix_reuse, self.cfg.name
        toks = list(tokens)
        n = len(toks)
        cuts = [0] + self.chunk_bounds(n, chunk_tokens) + [n]
        out: Optional[PrefillOutput] = None
        for lo, hi in zip(cuts, cuts[1:]):
            chunk = toks[lo:hi]
            if lo == 0:
                out = self.run(
                    [chunk],
                    frames=[frames] if frames is not None else None)[0]
            else:
                pkv = None
                if out.k is not None:
                    pkv = jnp.concatenate([out.k, out.v], axis=-1)
                out = self.run_suffix(
                    chunk, prefix_kv=pkv, frames=frames,
                    state=out.mamba_state
                    if self.requires_state_restore else None,
                    prefix_len=lo)
            self.chunked_chunks += 1
            yield hi - lo, out
        self.chunked_prefills += 1

    def run_chunked(self, tokens: Sequence[int], *, chunk_tokens: int,
                    frames: Optional[object] = None) -> PrefillOutput:
        """Drain ``iter_chunks``; returns the full-prompt output."""
        out: Optional[PrefillOutput] = None
        for _, out in self.iter_chunks(tokens, chunk_tokens=chunk_tokens,
                                       frames=frames):
            pass
        assert out is not None
        return out


class DecodeEngine:
    """Continuous-batched paged decode over a PagedKVPool.

    Slot state lives in fixed-shape padded arrays over ``max_slots``
    (tokens / positions / active mask / power-of-two bucketed block
    table, plus block-stacked mamba and cross-attention buffers from
    ``caches.decode_slot_state``), so the fused path runs the whole
    iteration as one jitted device program with the pool storage and
    slot buffers donated: one dispatch, one host transfer (the argmax),
    zero per-layer pool copies. Retraces happen only when the block
    table grows past its bucket (bounded by log2(pool blocks)).

    ``fused=False`` keeps the eager per-layer loop: one dispatch per
    sublayer, a whole-pool copy per attention layer, a host sync per
    step — the measured baseline in benchmarks/bench_decode.py.

    ``spec=`` (a ``SpecConfig``) switches the fused step to the
    speculative propose/verify program
    (``models.modeling.spec_decode_step_jit``): draft and target run in
    ONE donated program and each slot retires 1..k+1 tokens per step
    (``step()`` then maps slots to token LISTS). The draft's paged KV
    rides the target's block tables in an engine-owned storage array,
    its recurrent/cross state in a second donated slot-state carry, and
    its prompt is prefilled at admission by an engine-owned draft
    PrefillEngine — the decode node never sees two models. Greedy
    speculation is lossless, so the emitted stream (and the paged pool,
    bit-for-bit) matches plain fused greedy decode.
    """

    def __init__(self, cfg: ModelConfig, params: Tree, pool: PagedKVPool,
                 *, max_slots: int = 8, fused: Optional[bool] = None,
                 spec: Optional[SpecConfig] = None):
        self.cfg = cfg
        self.params = params
        self.pool = pool
        self.max_slots = max_slots
        self.fused = True if fused is None else bool(fused)
        self.spec = spec
        if spec is not None:
            assert self.fused, "speculative decode requires the fused step"
            assert not cfg.is_encoder_decoder, \
                "speculative decode does not cover enc-dec families yet"
            d_attn = len(_attn_layer_order(spec.draft_cfg))
            self._d_storage = jnp.zeros(
                (max(d_attn, 1), pool.num_blocks, pool.block_size,
                 2 * spec.draft_cfg.kv_dim), pool.dtype)
            self._d_slot_layers = decode_slot_state(spec.draft_cfg,
                                                    max_slots)
            # cold draft prompt prefill at admission (the draft has no
            # prefix store; its whole cache is rebuilt per admission)
            self._d_prefill = PrefillEngine(spec.draft_cfg,
                                            spec.draft_params)
        self._attn_order = _attn_layer_order(cfg)
        self._mamba_order = _mamba_layer_order(cfg)
        # slot state: host mirrors (admission bookkeeping) ...
        self.rid = [None] * max_slots
        self.pos = np.zeros(max_slots, np.int64)      # tokens so far
        self.last_tok = np.zeros(max_slots, np.int32)
        # ... and fixed-shape device state for the fused step
        self._slot_layers = decode_slot_state(cfg, max_slots)
        self._tokens = jnp.zeros((max_slots,), jnp.int32)
        self._pos = jnp.zeros((max_slots,), jnp.int32)
        self._active = jnp.zeros((max_slots,), bool)
        self._table_w = 1                             # pow2 table bucket
        self._table = jnp.full((max_slots, 1), -1, jnp.int32)
        self._caps = np.zeros(max_slots, np.int64)    # tokens allocatable
        self._caps_dev = jnp.zeros((max_slots,), jnp.int32)
        self._dirty = True        # host mirrors ahead of device arrays
        self.fused_steps = 0
        self.eager_steps = 0
        self.spec_steps = 0       # fused speculative iterations
        self.spec_emitted = 0     # tokens retired by those iterations

    # ------------------------------------------------------------- slots
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.rid) if r is None]

    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.rid) if r is not None]

    def admit(self, rid: int, out: PrefillOutput, blocks: Sequence[int],
              slot: Optional[int] = None,
              prompt: Optional[Sequence[int]] = None) -> int:
        """Attach a transferred request to a free slot. The KV for its
        prompt must already be in `self.pool` under `blocks`, and the
        request's FULL block allocation (prompt + generation room) must
        be in place — the fused step snapshots the block table here.

        In ``spec=`` mode the caller must also pass the request's
        ``prompt`` tokens: the draft model sees no transferred KV (only
        the target's prefill crossed the wire), so the engine prefills
        the draft here and seeds its KV/recurrent slot state alongside
        the target's."""
        if slot is None:
            free = self.free_slots()
            if not free:
                raise RuntimeError("no free decode slot")
            slot = free[0]
        self.rid[slot] = rid
        self.pos[slot] = out.prompt_len
        self.last_tok[slot] = out.first_token
        for (bk, sb), st in (out.mamba_state or {}).items():
            buf = self._slot_layers[f"sub{sb}"]
            for k2 in ("conv_x", "conv_b", "conv_c", "state"):
                buf[k2] = buf[k2].at[bk, slot].set(
                    st[k2].astype(buf[k2].dtype))
        for (bk, sb), (xk, xv) in (out.cross or {}).items():
            buf = self._slot_layers[f"sub{sb}"]
            buf["xk"] = buf["xk"].at[bk, slot].set(xk.astype(buf["xk"].dtype))
            buf["xv"] = buf["xv"].at[bk, slot].set(xv.astype(buf["xv"].dtype))
        if self.spec is not None:
            if prompt is None:
                raise ValueError(
                    "spec-mode admission needs the prompt tokens (the "
                    "draft model prefills here, at the decode node)")
            self._admit_draft(slot, list(prompt), blocks)
        self._dirty = True
        return slot

    def _admit_draft(self, slot: int, prompt: List[int],
                     blocks: Sequence[int]):
        """Cold draft prompt prefill + slot seeding: draft KV is written
        into the engine-owned draft storage at the TARGET's blocks (the
        draft rides the target's block tables), draft recurrent state
        into the draft slot-state carry."""
        d_out = self._d_prefill.run([prompt])[0]
        if d_out.k is not None:
            bs = self.pool.block_size
            toks = np.arange(d_out.prompt_len)
            blk = jnp.asarray(np.asarray(list(blocks))[toks // bs])
            off = jnp.asarray(toks % bs)
            kv = jnp.concatenate([d_out.k, d_out.v],
                                 axis=-1).astype(self._d_storage.dtype)
            self._d_storage = self._d_storage.at[:, blk, off].set(kv)
        for (bk, sb), st in (d_out.mamba_state or {}).items():
            buf = self._d_slot_layers[f"sub{sb}"]
            for k2 in ("conv_x", "conv_b", "conv_c", "state"):
                buf[k2] = buf[k2].at[bk, slot].set(
                    st[k2].astype(buf[k2].dtype))

    def evict(self, slot: int):
        self.rid[slot] = None
        self.pos[slot] = 0
        self.last_tok[slot] = 0
        self._dirty = True

    def evict_all(self) -> List[int]:
        """Clear every active slot in one sweep — the crash-recovery
        wipe (serving/faults.py): a dead/ejected node's in-flight
        requests are re-admitted elsewhere, so its slot state must not
        survive into a rejoin."""
        slots = self.active_slots()
        for s in slots:
            self.evict(s)
        return slots

    # -------------------------------------------------------------- step
    def step(self) -> Dict[int, int]:
        """One decode iteration over all active slots.
        Returns {slot: next_token} — or, in ``spec=`` mode,
        {slot: [token, ...]} with 1..k+1 tokens retiring per slot."""
        if self.spec is not None:
            return self._step_spec()
        if self.fused:
            return self._step_fused()
        return self._step_eager()

    def _sync_device(self):
        """Push host slot mirrors into the fixed-shape device arrays.
        Runs only after admissions/evictions (membership changes) — the
        steady-state fused loop touches no host state on the way in."""
        need = max((len(self.pool.owned(r)) for r in self.rid
                    if r is not None), default=1)
        while self._table_w < need:
            self._table_w *= 2
        self._tokens = jnp.asarray(self.last_tok)
        self._pos = jnp.asarray(self.pos.astype(np.int32))
        self._active = jnp.asarray(
            np.asarray([r is not None for r in self.rid]))
        self._table = jnp.asarray(
            self.pool.block_tables(list(self.rid), self._table_w))
        bs = self.pool.block_size
        self._caps = np.asarray(
            [len(self.pool.owned(r)) * bs if r is not None else 0
             for r in self.rid], np.int64)
        self._caps_dev = jnp.asarray(self._caps.astype(np.int32))
        self._dirty = False

    def _step_fused(self) -> Dict[int, int]:
        act = self.active_slots()
        if not act:
            return {}
        if self._dirty:
            self._sync_device()
        # the device scatter clamps indices, which would silently
        # overwrite earlier KV on allocation overflow — fail loudly like
        # the eager loop's Python indexing instead (caps snapshotted at
        # sync: allocations are fixed from admit onward)
        over = np.nonzero(self.pos >= self._caps)[0]
        over = [s for s in over if self.rid[s] is not None]
        if over:
            s_i = over[0]
            raise IndexError(
                f"slot {s_i} (rid {self.rid[s_i]}): token position "
                f"{int(self.pos[s_i])} outside its "
                f"{int(self._caps[s_i])}-token block allocation")
        nxt, toks, pos, storage, layers = decode_step_jit(
            self.cfg, self.params, self.pool.storage, self._table,
            self._tokens, self._pos, self._active, self._slot_layers,
            block_size=self.pool.block_size)
        self.pool.set_storage(storage)       # donated: updated in place
        self._slot_layers = layers
        self._tokens, self._pos = toks, pos
        self.fused_steps += 1
        out_np = np.asarray(nxt)             # the ONE host sync per step
        out: Dict[int, int] = {}
        for s_i in act:
            self.pos[s_i] += 1
            self.last_tok[s_i] = out_np[s_i]
            out[s_i] = int(out_np[s_i])
        return out

    def _step_spec(self) -> Dict[int, List[int]]:
        """One fused speculative iteration: {slot: emitted tokens},
        1..k+1 per active slot. Mirrors ``_step_fused`` — same loud
        overflow check, same donation adoption, still exactly ONE
        device->host transfer (the packed (slots, k+2) out matrix)."""
        act = self.active_slots()
        if not act:
            return {}
        if self._dirty:
            self._sync_device()
        over = np.nonzero(self.pos >= self._caps)[0]
        over = [s for s in over if self.rid[s] is not None]
        if over:
            s_i = over[0]
            raise IndexError(
                f"slot {s_i} (rid {self.rid[s_i]}): token position "
                f"{int(self.pos[s_i])} outside its "
                f"{int(self._caps[s_i])}-token block allocation")
        k = self.spec.k
        (packed, toks, pos, storage, d_storage, layers,
         d_layers) = spec_decode_step_jit(
            self.cfg, self.spec.draft_cfg, self.params,
            self.spec.draft_params, self.pool.storage, self._d_storage,
            self._table, self._tokens, self._pos, self._active,
            self._caps_dev, self._slot_layers, self._d_slot_layers,
            block_size=self.pool.block_size, k=k)
        self.pool.set_storage(storage)       # donated: updated in place
        self._d_storage = d_storage
        self._slot_layers, self._d_slot_layers = layers, d_layers
        self._tokens, self._pos = toks, pos
        self.fused_steps += 1
        self.spec_steps += 1
        out_np = np.asarray(packed)          # the ONE host sync per step
        out: Dict[int, List[int]] = {}
        for s_i in act:
            n = int(out_np[s_i, k + 1])
            emit = [int(t) for t in out_np[s_i, :n]]
            self.pos[s_i] += n
            self.last_tok[s_i] = emit[-1]
            self.spec_emitted += n
            out[s_i] = emit
        return out

    def _step_eager(self) -> Dict[int, int]:
        """Legacy per-layer loop (benchmark baseline): every sublayer is
        a separate dispatch and each attention layer swaps a full copy
        of the paged pool."""
        cfg = self.cfg
        act = self.active_slots()
        if not act:
            return {}
        act_arr = np.asarray(act)
        toks = jnp.asarray(self.last_tok[act_arr])
        pos = jnp.asarray(self.pos[act_arr])          # tokens so far
        h = self.params["embed"][toks].astype(jnp.float32)
        period = block_period(cfg)
        kinds = cfg.layer_kinds()
        moe_mask = cfg.moe_layer_mask()
        attn_idx = {pair: i for i, pair in enumerate(self._attn_order)}
        # block tables sized to the largest allocation among active slots
        nblocks = max(len(self.pool.owned(self.rid[s])) for s in act)
        bt = jnp.asarray(self.pool.block_tables(
            [self.rid[s] for s in act], nblocks))
        lens = pos + 1                                 # incl. current token
        for bk in range(num_blocks(cfg)):
            for sb in range(period):
                p = _slice_layer(self.params["blocks"][f"sub{sb}"], bk)
                if kinds[sb] == ATTN:
                    li = attn_idx[(bk, sb)]
                    x = rmsnorm(h, p["norm"], cfg.norm_eps)
                    q, k, v = _attn_proj_qkv(p, x[:, None, :], cfg)
                    q4 = _split_heads(q[:, 0], cfg.num_heads)
                    k4 = _split_heads(k[:, 0], cfg.num_kv_heads)
                    q4 = rope(q4, pos, cfg.rope_theta)
                    k4 = rope(k4, pos, cfg.rope_theta)
                    kf, vf = _merge_heads(k4), v[:, 0]
                    # write the token into the pool at (block, offset)
                    blk_ids, offs = [], []
                    for s_i in act:
                        bl = self.pool.owned(self.rid[s_i])
                        t = int(self.pos[s_i])
                        blk_ids.append(bl[t // self.pool.block_size])
                        offs.append(t % self.pool.block_size)
                    kv_tok = jnp.concatenate([kf, vf], -1).astype(
                        self.pool.dtype)
                    self.pool.set_storage(self.pool.storage.at[
                        li, jnp.asarray(blk_ids), jnp.asarray(offs)
                    ].set(kv_tok))
                    o = ops.paged_attention(
                        q4.astype(self.pool.dtype),
                        self.pool.storage[li], bt,
                        lens.astype(jnp.int32))
                    h = h + _merge_heads(o).astype(h.dtype) @ p["wo"]
                else:
                    buf = self._slot_layers[f"sub{sb}"]
                    cin = {k2: buf[k2][bk, act_arr]
                           for k2 in ("conv_x", "conv_b", "conv_c",
                                      "state")}
                    h, nc = mamba_sublayer_step(p, h, cin, cfg)
                    for k2, v2 in nc.items():
                        buf[k2] = buf[k2].at[bk, act_arr].set(
                            v2.astype(buf[k2].dtype))
                if cfg.is_encoder_decoder:
                    from repro.models.modeling import attention_decode
                    buf = self._slot_layers[f"sub{sb}"]
                    xk = buf["xk"][bk, act_arr]
                    xv = buf["xv"][bk, act_arr]
                    x = rmsnorm(h, p["norm_x"], cfg.norm_eps)
                    q4 = _split_heads(x @ p["wqx"], cfg.num_heads)
                    o = attention_decode(
                        q4.astype(jnp.float32), xk, xv,
                        cfg.num_kv_heads,
                        jnp.asarray(cfg.encoder_seq), window=None)
                    h = h + _merge_heads(o).astype(h.dtype) @ p["wox"]
                h2, _ = _ffn_sublayer(p, h[:, None, :], cfg, moe_mask[sb])
                h = h2[:, 0]
        h = rmsnorm(h, self.params["final_norm"], cfg.norm_eps)
        logits = lm_logits(cfg, self.params, h)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self.eager_steps += 1
        self._dirty = True       # device token/pos mirrors are now stale
        out: Dict[int, int] = {}
        for j, s_i in enumerate(act):
            self.pos[s_i] += 1
            self.last_tok[s_i] = nxt[j]
            out[s_i] = int(nxt[j])
        return out
