"""Model configuration system.

A single generic config describes every assigned architecture family:
dense GQA transformers, MoE (shared + routed experts), Mamba2 SSD, hybrid
(attention/mamba interleave a la Jamba), encoder-decoder (Whisper) and
VLM decoders with stubbed modality frontends.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple


# Layer kinds used in `layer_pattern`.
ATTN = "attn"
MAMBA = "mamba"


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int            # routed experts
    top_k: int
    num_shared_experts: int = 0
    d_ff_expert: int = 0        # per-expert ffn hidden dim (routed and shared)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # capacity accounting window (tokens): expert slots are counted
    # inside fixed windows of this many consecutive tokens per row,
    # aligned to the row start. Window-local counting is what makes
    # capacity dispatch right-pad-invariant (pads route to a null slot
    # and the slot threshold comes from the window's VALID token count)
    # and prefix-transparent (a suffix-only prefill whose prefix length
    # is a multiple of the window sees exactly the windows a full
    # prefill would give its suffix tokens).
    capacity_window: int = 16
    # which layers are MoE: "all" | "every_other" | "none"
    layout: str = "all"
    # dispatch algorithm: "capacity" (GShard-style scatter, may drop) or
    # "sorted" (argsort + ragged_dot, dropless — §Perf E-series lever)
    dispatch: str = "capacity"


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64          # Mamba2 "P"
    expand: int = 2
    conv_kernel: int = 4
    n_groups: int = 1
    chunk: int = 256            # SSD chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str              # dense | moe | vlm | audio | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # sliding-window attention (None = full causal). Used by long_500k decode.
    sliding_window: Optional[int] = None
    # hybrid interleave: one entry per layer in a repeating block,
    # e.g. ("attn",) for pure transformers, ("attn",)+("mamba",)*7 for Jamba.
    layer_block: Tuple[str, ...] = (ATTN,)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # encoder-decoder (whisper): encoder layer count; 0 = decoder-only.
    encoder_layers: int = 0
    encoder_seq: int = 0        # fixed encoder length (e.g. 1500 audio frames)
    # modality frontend stub: None | "audio" | "vision".
    frontend: Optional[str] = None
    max_seq_len: int = 131072

    # ---- derived ----
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.hd

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attn_free(self) -> bool:
        return ATTN not in self.layer_block

    def layer_kinds(self) -> Tuple[str, ...]:
        """Expanded per-layer kind list of length num_layers."""
        blk = self.layer_block
        reps = -(-self.num_layers // len(blk))
        return tuple((blk * reps)[: self.num_layers])

    def moe_layer_mask(self) -> Tuple[bool, ...]:
        if self.moe is None or self.moe.layout == "none":
            return tuple(False for _ in range(self.num_layers))
        if self.moe.layout == "all":
            return tuple(True for _ in range(self.num_layers))
        if self.moe.layout == "every_other":
            return tuple(i % 2 == 1 for i in range(self.num_layers))
        raise ValueError(self.moe.layout)

    @property
    def ssm_cfg(self) -> SSMConfig:
        assert self.ssm is not None
        return self.ssm

    # -- parameter count (for 6ND roofline term) --
    def param_count(self, active_only: bool = False) -> int:
        d, ff, hd = self.d_model, self.d_ff, self.hd
        n = 0
        emb = self.vocab_size * d
        n += emb
        if not self.tie_embeddings:
            n += emb  # lm head
        kinds = self.layer_kinds()
        moe_mask = self.moe_layer_mask()
        for i in range(self.num_layers):
            n += 2 * d  # two norms
            if kinds[i] == ATTN:
                n += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                if self.qkv_bias:
                    n += self.q_dim + 2 * self.kv_dim
            else:
                s = self.ssm_cfg
                d_in = s.expand * d
                conv_dim = d_in + 2 * s.n_groups * s.d_state
                nheads = d_in // s.head_dim
                n += d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)  # in_proj
                n += conv_dim * s.conv_kernel
                n += nheads * 2 + d_in  # A_log, D, dt_bias approx
                n += d_in * d  # out_proj
            if moe_mask[i]:
                m = self.moe
                ffe = m.d_ff_expert or ff
                per_exp = 3 * d * ffe
                if active_only:
                    n += (m.top_k + m.num_shared_experts) * per_exp
                    n += d * m.num_experts  # router
                else:
                    n += (m.num_experts + m.num_shared_experts) * per_exp
                    n += d * m.num_experts
            elif ff > 0:
                n += 3 * d * ff  # gated mlp
        # encoder (whisper)
        for _ in range(self.encoder_layers):
            n += 2 * d
            n += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            n += 3 * d * ff
        if self.is_encoder_decoder:
            # decoder cross-attention per layer
            n += self.num_layers * (d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d)
        n += d  # final norm
        return n

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        period = len(self.layer_block)
        if self.moe is not None and self.moe.layout == "every_other":
            period = period * 2 // math.gcd(period, 2)
        kw = dict(
            name=self.name + "-reduced",
            num_layers=max(min(self.num_layers, 2), period),
            d_model=min(self.d_model, 128),
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            max_seq_len=4096,
        )
        nh = min(self.num_heads, 4)
        nkv = min(self.num_kv_heads, nh)
        # keep GQA ratio flavour: if original had grouped kv, keep 2 kv heads
        if self.num_kv_heads < self.num_heads:
            nkv = max(1, nh // 2)
        kw.update(num_heads=nh, num_kv_heads=nkv, head_dim=32)
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                d_ff_expert=min(self.moe.d_ff_expert or 256, 64),
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk=32)
        if self.encoder_layers:
            kw["encoder_layers"] = 2
            kw["encoder_seq"] = 16
        if self.sliding_window is not None:
            kw["sliding_window"] = min(self.sliding_window, 64)
        return self.replace(**kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Sliding window used for dense archs on long_500k (sub-quadratic variant).
LONG_CONTEXT_WINDOW = 8192
