"""Step functions lowered by the launcher / dry-run and used by examples.

  * train_step(params, opt_state, batch) -> (params, opt_state, metrics)
  * prefill_step(params, batch) -> (first_token, cache)
  * serve_step(params, cache, tokens) -> (next_token, cache)     [ONE token]
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distribution.ctx import sharding_ctx
from repro.models.config import LONG_CONTEXT_WINDOW, ModelConfig, ShapeConfig
from repro.models.modeling import forward_decode, forward_prefill, forward_train
from repro.training.optimizer import AdamWConfig, adamw_update

Tree = Dict[str, Any]


def decode_window(cfg: ModelConfig, shape: ShapeConfig) -> Optional[int]:
    """Sliding-window policy: long_500k on attention archs uses the
    ring-buffer windowed variant (sub-quadratic); everything else is full."""
    if shape.kind != "decode":
        return None
    if shape.seq_len > 131072 and not cfg.attn_free:
        return min(LONG_CONTEXT_WINDOW, shape.seq_len)
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, shape.seq_len)
    return None


def make_train_step(cfg: ModelConfig, opt: AdamWConfig = AdamWConfig(),
                    remat: bool = True, mesh=None, microbatches: int = 1):
    """microbatches > 1 enables gradient accumulation: the global batch is
    processed in M sequential slices, dividing activation transients and the
    remat carry stack by M at the cost of M smaller collectives."""

    def grads_of(params: Tree, batch: Tree):
        def loss_fn(p):
            return forward_train(cfg, p, batch, remat=remat)
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(params: Tree, opt_state: Tree, batch: Tree):
        with sharding_ctx(mesh):
            if microbatches <= 1:
                (loss, metrics), grads = grads_of(params, batch)
            else:
                def resh(x):
                    b = x.shape[0]
                    assert b % microbatches == 0, (b, microbatches)
                    return x.reshape((microbatches, b // microbatches)
                                     + x.shape[1:])

                mb = jax.tree.map(resh, batch)
                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def body(acc, mbatch):
                    (loss, metrics), g = grads_of(params, mbatch)
                    acc = jax.tree.map(
                        lambda a, x: a + x.astype(jnp.float32), acc, g)
                    return acc, (loss, metrics)

                grads, (losses, metricses) = jax.lax.scan(body, g0, mb)
                grads = jax.tree.map(lambda g: g / microbatches, grads)
                loss = jnp.mean(losses)
                metrics = jax.tree.map(jnp.mean, metricses)
            new_params, new_opt, gnorm = adamw_update(
                params, grads, opt_state, opt)
            metrics = dict(metrics, loss=loss, grad_norm=gnorm)
            return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, window: Optional[int] = None,
                      mesh=None, act_rules=None):
    def prefill_step(params: Tree, batch: Tree):
        with sharding_ctx(mesh, act_rules):
            return forward_prefill(cfg, params, batch, window=window)

    return prefill_step


def make_serve_step(cfg: ModelConfig, window: Optional[int] = None,
                    mesh=None):
    def serve_step(params: Tree, cache: Tree, tokens: jax.Array):
        with sharding_ctx(mesh):
            return forward_decode(cfg, params, cache, tokens, window=window)

    return serve_step
