"""Decode-cache construction: shapes, abstract specs, logical axes.

Flat per-layer KV layout (b, S, kv_dim) — contiguous bytes, the layout the
paper's block-free D2D transfer (C3) wants, and always divisibly shardable
on the `model` axis (kv_dim = num_kv_heads * head_dim is a multiple of 16
for every assigned arch, unlike the head count itself).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ATTN, ModelConfig
from repro.models.params import block_period, num_blocks

Tree = Dict[str, Any]


def cache_shapes(cfg: ModelConfig, batch: int, seq: int, *,
                 window: Optional[int] = None) -> Tree:
    """Shape/axes tree for the decode cache.

    Leaves are (shape, axes) tuples; axes use logical names consumed by
    repro.distribution.sharding.
    """
    nblk = num_blocks(cfg)
    period = block_period(cfg)
    kinds = cfg.layer_kinds()
    S = window if window is not None else seq
    layers: Tree = {}
    for i in range(period):
        c: Tree = {}
        if kinds[i] == ATTN:
            c["k"] = ((nblk, batch, S, cfg.kv_dim),
                      ("layers", "batch", "cache_seq", "kv_heads"))
            c["v"] = ((nblk, batch, S, cfg.kv_dim),
                      ("layers", "batch", "cache_seq", "kv_heads"))
        else:
            s = cfg.ssm_cfg
            d_in = s.expand * cfg.d_model
            gn = s.n_groups * s.d_state
            nh = d_in // s.head_dim
            k = s.conv_kernel
            c["conv_x"] = ((nblk, batch, d_in, k - 1),
                           ("layers", "batch", "d_inner", None))
            c["conv_b"] = ((nblk, batch, gn, k - 1),
                           ("layers", "batch", None, None))
            c["conv_c"] = ((nblk, batch, gn, k - 1),
                           ("layers", "batch", None, None))
            c["state"] = ((nblk, batch, nh, s.d_state, s.head_dim),
                          ("layers", "batch", None, None, None))
        if cfg.is_encoder_decoder:
            c["xk"] = ((nblk, batch, cfg.encoder_seq, cfg.kv_dim),
                       ("layers", "batch", None, "kv_heads"))
            c["xv"] = ((nblk, batch, cfg.encoder_seq, cfg.kv_dim),
                       ("layers", "batch", None, "kv_heads"))
        layers[f"sub{i}"] = c
    return {"layers": layers, "pos": ((), ())}


def abstract_cache(cfg: ModelConfig, batch: int, seq: int, *,
                   window: Optional[int] = None,
                   dtype=jnp.bfloat16) -> Tree:
    tree = cache_shapes(cfg, batch, seq, window=window)

    def mk(path, leaf):
        shape, _ = leaf
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if shape == ():
            dt = jnp.int32
        elif name == "state":
            dt = jnp.float32  # SSD state accumulates; keep full precision
        else:
            dt = dtype
        return jax.ShapeDtypeStruct(shape, dt)
    return jax.tree_util.tree_map_with_path(mk, tree, is_leaf=_is_leaf)


def cache_axes(cfg: ModelConfig, batch: int, seq: int, *,
               window: Optional[int] = None) -> Tree:
    return jax.tree.map(lambda leaf: leaf[1],
                        cache_shapes(cfg, batch, seq, window=window),
                        is_leaf=_is_leaf)


def zeros_cache(cfg: ModelConfig, batch: int, seq: int, *,
                window: Optional[int] = None, dtype=jnp.float32,
                pos: int = 0) -> Tree:
    def mk(sds):
        if sds.shape == ():
            return jnp.asarray(pos, jnp.int32)
        dt = jnp.float32 if sds.dtype == jnp.float32 else dtype
        return jnp.zeros(sds.shape, dt)
    return jax.tree.map(mk, abstract_cache(cfg, batch, seq, window=window,
                                           dtype=dtype))


def cache_num_bytes(cfg: ModelConfig, batch: int, seq: int, *,
                    window: Optional[int] = None, bytes_per_el: int = 2) -> int:
    import numpy as np
    tree = cache_shapes(cfg, batch, seq, window=window)
    return sum(int(np.prod(shape)) * bytes_per_el
               for shape, _ in jax.tree.leaves(tree, is_leaf=_is_leaf)
               if shape != ())


def decode_slot_state(cfg: ModelConfig, max_slots: int,
                      dtype=jnp.float32) -> Tree:
    """Zeroed per-slot decode state for the serving DecodeEngine, in the
    fused-step layout: {"sub{i}": {...}} with every leaf stacked on a
    leading num_blocks axis, batch dim == max_slots — the fixed-shape
    twin of the lockstep decode cache (KV lives in the paged pool
    instead, so attention sublayers carry no entry here). Mamba conv
    tails + SSD state for SSM sublayers; enc-dec adds the per-request
    cross-attention KV to every sublayer.
    """
    nblk = num_blocks(cfg)
    period = block_period(cfg)
    kinds = cfg.layer_kinds()
    layers: Tree = {}
    for i in range(period):
        c: Tree = {}
        if kinds[i] != ATTN:
            s = cfg.ssm_cfg
            d_in = s.expand * cfg.d_model
            gn = s.n_groups * s.d_state
            nh = d_in // s.head_dim
            k = s.conv_kernel
            c["conv_x"] = jnp.zeros((nblk, max_slots, d_in, k - 1), dtype)
            c["conv_b"] = jnp.zeros((nblk, max_slots, gn, k - 1), dtype)
            c["conv_c"] = jnp.zeros((nblk, max_slots, gn, k - 1), dtype)
            c["state"] = jnp.zeros(
                (nblk, max_slots, nh, s.d_state, s.head_dim), jnp.float32)
        if cfg.is_encoder_decoder:
            c["xk"] = jnp.zeros(
                (nblk, max_slots, cfg.encoder_seq, cfg.kv_dim), dtype)
            c["xv"] = jnp.zeros(
                (nblk, max_slots, cfg.encoder_seq, cfg.kv_dim), dtype)
        layers[f"sub{i}"] = c
    return layers


def select_slot_state(stacked: Tree, idx: jax.Array) -> Tree:
    """Per-slot selection out of a micro-step state stack.

    ``stacked`` is a decode_slot_state tree whose every leaf grew a
    leading micro-step axis — (k+1, nblk, max_slots, ...) — from
    ``lax.scan`` stacking the post-state of each speculative micro-step.
    ``idx`` (max_slots,) int32 picks, PER SLOT, which micro-step's state
    to keep (the speculative rollback: depth ``n_emit - 1``). Pure
    gather — no replay, no retrace: idx is data.
    """
    def f(x):
        ix = idx.astype(jnp.int32).reshape(
            (1, 1, -1) + (1,) * (x.ndim - 3))
        ix = jnp.broadcast_to(ix, (1,) + x.shape[1:])
        return jnp.take_along_axis(x, ix, axis=0)[0]
    return jax.tree.map(f, stacked)


def _is_leaf(x) -> bool:
    return isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)
