"""Parameter spec trees: one source of truth for shapes, logical axes, init.

Every model parameter is described by a ParamSpec carrying its shape and
logical axis names. From the spec tree we derive:
  * materialized params (for CPU tests / real serving),
  * abstract params (ShapeDtypeStruct, for the multi-pod dry-run),
  * shardings (logical axes -> mesh axes via mode rules in
    repro.distribution.sharding).

Identical layers are stacked along a leading 'layers' axis and executed
with lax.scan. Heterogeneous interleaves (Jamba) stack per *sub-position*
within the repeating block: params["blocks"]["sub3"] holds the stacked
params of every layer whose index % period == 3.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ATTN, MAMBA, ModelConfig

Tree = Dict[str, Any]


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis per dim (None = never sharded)
    init: str = "normal"             # normal | zeros | ones | small_normal
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _attn_specs(cfg: ModelConfig, cross: bool = False) -> Tree:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    sfx = "x" if cross else ""
    t: Tree = {
        f"wq{sfx}": ParamSpec((d, qd), ("embed", "q_heads")),
        f"wk{sfx}": ParamSpec((d, kvd), ("embed", "kv_heads")),
        f"wv{sfx}": ParamSpec((d, kvd), ("embed", "kv_heads")),
        f"wo{sfx}": ParamSpec((qd, d), ("q_heads", "embed")),
    }
    if cfg.qkv_bias and not cross:
        t[f"bq{sfx}"] = ParamSpec((qd,), ("q_heads",), init="zeros")
        t[f"bk{sfx}"] = ParamSpec((kvd,), ("kv_heads",), init="zeros")
        t[f"bv{sfx}"] = ParamSpec((kvd,), ("kv_heads",), init="zeros")
    return t


def _mlp_specs(d: int, ff: int) -> Tree:
    return {
        "w_gate": ParamSpec((d, ff), ("embed", "ff")),
        "w_up": ParamSpec((d, ff), ("embed", "ff")),
        "w_down": ParamSpec((ff, d), ("ff", "embed")),
    }


def _moe_specs(cfg: ModelConfig) -> Tree:
    m = cfg.moe
    d = cfg.d_model
    ffe = m.d_ff_expert or cfg.d_ff
    t: Tree = {
        "router": ParamSpec((d, m.num_experts), ("embed", None)),
        "w_gate": ParamSpec((m.num_experts, d, ffe), ("expert", "embed", "ff")),
        "w_up": ParamSpec((m.num_experts, d, ffe), ("expert", "embed", "ff")),
        "w_down": ParamSpec((m.num_experts, ffe, d), ("expert", "ff", "embed")),
    }
    if m.num_shared_experts:
        sff = m.num_shared_experts * ffe
        t["shared"] = _mlp_specs(d, sff)
    return t


def _mamba_specs(cfg: ModelConfig) -> Tree:
    s = cfg.ssm_cfg
    d = cfg.d_model
    d_in = s.expand * d
    gn = s.n_groups * s.d_state
    nh = d_in // s.head_dim
    k = s.conv_kernel
    return {
        "w_z": ParamSpec((d, d_in), ("embed", "d_inner")),
        "w_x": ParamSpec((d, d_in), ("embed", "d_inner")),
        "w_b": ParamSpec((d, gn), ("embed", None)),
        "w_c": ParamSpec((d, gn), ("embed", None)),
        "w_dt": ParamSpec((d, nh), ("embed", None)),
        "conv_x": ParamSpec((d_in, k), ("d_inner", None), init="small_normal"),
        "conv_b": ParamSpec((gn, k), (None, None), init="small_normal"),
        "conv_c": ParamSpec((gn, k), (None, None), init="small_normal"),
        "a_log": ParamSpec((nh,), (None,), init="ones"),
        "d_skip": ParamSpec((nh,), (None,), init="ones"),
        "dt_bias": ParamSpec((nh,), (None,), init="zeros"),
        "norm_g": ParamSpec((d_in,), ("d_inner",), init="ones"),
        "w_out": ParamSpec((d_in, d), ("d_inner", "embed")),
    }


def sublayer_specs(cfg: ModelConfig, sub: int, *, decoder: bool = True) -> Tree:
    """Spec tree for one sub-position of the repeating block (unstacked)."""
    kinds = cfg.layer_kinds()
    moe_mask = cfg.moe_layer_mask()
    kind = kinds[sub]
    is_moe = moe_mask[sub]
    d = cfg.d_model
    t: Tree = {"norm": ParamSpec((d,), ("embed",), init="ones")}
    if kind == ATTN:
        t.update(_attn_specs(cfg))
    else:
        t.update(_mamba_specs(cfg))
    if decoder and cfg.is_encoder_decoder:
        t["norm_x"] = ParamSpec((d,), ("embed",), init="ones")
        t.update(_attn_specs(cfg, cross=True))
    if is_moe:
        t["norm2"] = ParamSpec((d,), ("embed",), init="ones")
        t["moe"] = _moe_specs(cfg)
    elif cfg.d_ff > 0:
        t["norm2"] = ParamSpec((d,), ("embed",), init="ones")
        t["mlp"] = _mlp_specs(d, cfg.d_ff)
    return t


def block_period(cfg: ModelConfig) -> int:
    p = len(cfg.layer_block)
    if cfg.moe is not None and cfg.moe.layout == "every_other":
        p = (p * 2) // math.gcd(p, 2)
    if cfg.num_layers % p != 0:
        raise ValueError(f"{cfg.name}: num_layers {cfg.num_layers} % period {p} != 0")
    return p


def num_blocks(cfg: ModelConfig) -> int:
    return cfg.num_layers // block_period(cfg)


def _stack(tree: Tree, n: int) -> Tree:
    """Add leading 'layers' axis of size n to every spec leaf."""
    def f(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale)
    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def param_specs(cfg: ModelConfig) -> Tree:
    d = cfg.d_model
    period = block_period(cfg)
    nblk = num_blocks(cfg)
    t: Tree = {
        "embed": ParamSpec((cfg.vocab_size, d), ("vocab", "embed")),
        "final_norm": ParamSpec((d,), ("embed",), init="ones"),
        "blocks": {
            f"sub{i}": _stack(sublayer_specs(cfg, i), nblk) for i in range(period)
        },
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = ParamSpec((d, cfg.vocab_size), ("embed", "vocab"))
    if cfg.is_encoder_decoder:
        enc_sub: Tree = {"norm": ParamSpec((d,), ("embed",), init="ones")}
        enc_sub.update(_attn_specs(cfg))
        enc_sub["norm2"] = ParamSpec((d,), ("embed",), init="ones")
        enc_sub["mlp"] = _mlp_specs(d, cfg.d_ff)
        t["encoder"] = {
            "blocks": {"sub0": _stack(enc_sub, cfg.encoder_layers)},
            "final_norm": ParamSpec((d,), ("embed",), init="ones"),
            "pos_embed": ParamSpec((cfg.encoder_seq, d), (None, "embed")),
        }
    return t


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def abstract_params(cfg: ModelConfig, dtype=jnp.float32) -> Tree:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), param_specs(cfg),
        is_leaf=_is_spec)


def param_axes(cfg: ModelConfig) -> Tree:
    return jax.tree.map(lambda s: s.axes, param_specs(cfg), is_leaf=_is_spec)


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32) -> Tree:
    specs = param_specs(cfg)
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))

    def mk(s: ParamSpec, k):
        if s.init == "zeros":
            return jnp.zeros(s.shape, dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, dtype)
        scale = s.scale if s.init == "normal" else s.scale * 0.5
        return (jax.random.normal(k, s.shape, jnp.float32) * scale).astype(dtype)

    return jax.tree.unflatten(treedef, [mk(s, k) for s, k in zip(leaves, keys)])


def param_count_actual(cfg: ModelConfig) -> int:
    specs = param_specs(cfg)
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=_is_spec))
