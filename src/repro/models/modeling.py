"""Pure-JAX forward passes for every assigned architecture family.

Three entry points:
  * ``forward_train``   — full-sequence forward + chunked LM loss.
  * ``forward_prefill`` — full-sequence forward, returns (first token logits,
                          decode cache) — the P in P/D.
  * ``forward_decode``  — ONE token against a cache — the D in P/D.

Design notes (TPU adaptation, see DESIGN.md §3):
  * layers are stacked and executed with lax.scan (small HLO, remat-able);
  * attention is chunked over query blocks (flash-style online masking is
    unnecessary on the lowering path: per-chunk score tiles stay bounded);
  * KV caches use a FLAT per-layer layout (b, S, kv_dim) so the cache is
    contiguous bytes — the exact layout the paper's block-free D2D transfer
    wants, and evenly shardable on the `model` axis regardless of head count;
  * Mamba2 uses the chunked SSD algorithm with a lax.scan over chunks
    (state carried, O(chunk^2) tiles — MXU friendly);
  * MoE uses scatter-based capacity dispatch (no (T,E,C) one-hot blowup).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distribution.ctx import constrain
from repro.models.caches import select_slot_state
from repro.models.config import ATTN, MAMBA, ModelConfig
from repro.models.params import block_period, num_blocks

Tree = Dict[str, Any]

DEFAULT_Q_CHUNK = 512


# ---------------------------------------------------------------- basics

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    # Variance via an f32-accumulating einsum, scaling in the compute dtype.
    # Writing the upcast as x.astype(f32)**2 materializes a full-tensor f32
    # buffer per norm under XLA-CPU (observed: +2GiB per norm on 8k-wide
    # models); the einsum form keeps f32 in the accumulator only.
    ss = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)
    var = (ss / x.shape[-1])[..., None]
    inv = lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * w


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, hd) or (..., heads, hd) with scalar positions."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., half)
    # broadcast over the heads dim (and any leading dims positions lack)
    while ang.ndim < x.ndim:
        ang = ang[..., None, :] if ang.ndim == x.ndim - 1 \
            else ang[None]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _split_heads(x: jax.Array, n: int) -> jax.Array:
    return x.reshape(x.shape[:-1] + (n, x.shape[-1] // n))


def _merge_heads(x: jax.Array) -> jax.Array:
    return x.reshape(x.shape[:-2] + (x.shape[-2] * x.shape[-1],))


# ---------------------------------------------------------------- attention

def attention_seq(q: jax.Array, k: jax.Array, v: jax.Array, nkv: int, *,
                  causal: bool, window: Optional[int] = None,
                  q_chunk: int = 0, q_offset=0,
                  prefix_pad: Optional[int] = None,
                  q_valid: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence attention, chunked over query blocks.

    q: (b, s, nq, hd); k, v: (b, sk, nkv, hd). Returns (b, s, nq, hd).

    ``q_offset`` is the absolute position of the first query row
    (chunked-prefill / prefix-reuse: queries are the suffix of a longer
    KV sequence). It may be a TRACED scalar when ``prefix_pad`` is set:
    ``prefix_pad`` declares that the first ``prefix_pad`` key rows are a
    reused-prefix region padded to a static bucket, of which only the
    first ``q_offset`` are real — padded prefix keys are masked out of
    every query's softmax, so warm prefix-reuse admissions share one
    compiled program per (prefix bucket, suffix bucket) instead of
    retracing per prefix length. Without ``prefix_pad``,
    sk == q_offset + s and every key row is real (legacy contract).

    ``q_valid`` (b,) marks how many leading query rows per batch row are
    real: padded queries attend to nothing (their probability rows are
    zeroed, output exactly 0), so right-pad bucketing can never write
    attention mass — or NaNs — into rows the engine later slices off.
    The Pallas lowering of the same contract is
    ``kernels.flash_prefill(..., q_offset=..., prefix_pad=...,
    q_valid=...)``.

    KV heads are expanded to the full query-head count: the (nkv, g)
    factorization of GQA is usually NOT shardable on the `model` axis
    (e.g. 8 kv x 8 groups on a 16-way axis) while nq itself is, and the
    expansion is a transient, head-sharded buffer — cheap next to the
    un-shardable score tiles it prevents.
    """
    b, s, nq, hd = q.shape
    if not q_chunk:
        # smaller score tiles when the head count cannot shard 16 ways
        # (e.g. minicpm's 36 heads) — the tile is then device-replicated
        q_chunk = DEFAULT_Q_CHUNK if nq % 16 == 0 else 128
    sk = k.shape[1]
    g = nq // nkv
    scale = 1.0 / math.sqrt(hd)
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    q = constrain(q, ("batch", None, "q_heads_act", None))
    k = constrain(k, ("batch", None, "q_heads_act", None))
    v = constrain(v, ("batch", None, "q_heads_act", None))
    kj = jnp.arange(sk)
    if prefix_pad is None:
        kpos, kvalid = kj, None
    else:
        # key row -> absolute position / validity: prefix slots sit at
        # their own index (real iff < q_offset), suffix slots continue
        # at q_offset
        is_pfx = kj < prefix_pad
        kpos = jnp.where(is_pfx, kj, q_offset + (kj - prefix_pad))
        kvalid = ~is_pfx | (kj < q_offset)

    def one_chunk(qi: jax.Array, c0: int) -> jax.Array:
        # qi: (b, c, nq, hd); c0: first query row's index within s
        c = qi.shape[1]
        scores = jnp.einsum("bqhd,bshd->bhqs", qi, k,
                            preferred_element_type=jnp.float32) * scale
        scores = constrain(scores, ("batch", "q_heads_act", None, None))
        qrel = c0 + jnp.arange(c)
        if causal:
            qpos = q_offset + qrel
            m = kpos[None, :] <= qpos[:, None]
            if kvalid is not None:
                m &= kvalid[None, :]
            if window is not None:
                m &= (qpos[:, None] - kpos[None, :]) < window
            scores = jnp.where(m[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        if q_valid is not None:
            # padded query rows attend to nothing: output exactly 0
            qm = qrel[None, :] < q_valid[:, None]           # (b, c)
            probs = probs * qm[:, None, :, None].astype(probs.dtype)
        return jnp.einsum("bhqs,bshd->bqhd", probs.astype(v.dtype), v)

    if s <= q_chunk or s % q_chunk != 0:
        return one_chunk(q, 0)
    nc = s // q_chunk
    qcs = jnp.moveaxis(q.reshape(b, nc, q_chunk, nq, hd), 1, 0)

    @jax.checkpoint
    def body(_, inp):
        i, qi = inp
        return None, one_chunk(qi, i * q_chunk)

    _, outs = lax.scan(body, None, (jnp.arange(nc), qcs))
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, nq, hd)


def attention_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     nkv: int, pos: jax.Array, *,
                     window: Optional[int] = None,
                     k_new: Optional[jax.Array] = None,
                     v_new: Optional[jax.Array] = None) -> jax.Array:
    """One-token attention against a flat cache.

    q: (b, nq, hd); k_cache/v_cache: (b, S, kv_dim) — the cache BEFORE the
    current token is written. The current token's k/v are passed separately
    as k_new/v_new (b, kv_dim), so the loop body reads the old cache slice
    and only ever writes the one-token update: no read-after-write hazard
    on the carried buffer, which keeps the while-loop cache in place
    (copy-insertion otherwise clones the whole cache each step).

    With `window`, the cache is a ring buffer of length S == window: the
    slot being overwritten (pos % S) is exactly the token that just fell
    out of the window, so valid slots are < min(pos, S) excluding it.
    """
    b, nq, hd = q.shape
    S = k_cache.shape[1]
    g = nq // nkv
    scale = 1.0 / math.sqrt(hd)
    k = _split_heads(k_cache, nkv)  # (b, S, nkv, hd)
    v = _split_heads(v_cache, nkv)
    qg = q.reshape(b, nkv, g, hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    slots = jnp.arange(S)
    if window is not None:
        valid = slots < jnp.minimum(pos, S)
        valid &= slots != (pos % S)
    else:
        valid = slots < pos
    scores = jnp.where(valid[None, None, None], scores, -1e30)
    if k_new is not None:
        kn = k_new.reshape(b, nkv, hd)
        vn = v_new.reshape(b, nkv, hd)
        s_new = jnp.einsum("bkgd,bkd->bkg", qg, kn,
                           preferred_element_type=jnp.float32) * scale
        scores = jnp.concatenate([scores, s_new[..., None]], axis=-1)
        probs = jax.nn.softmax(scores, axis=-1)
        pc, pn = probs[..., :S], probs[..., S]
        out = jnp.einsum("bkgs,bskd->bkgd", pc.astype(v.dtype), v)
        out = out + pn.astype(v.dtype)[..., None] * vn[:, :, None, :]
    else:
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgs,bskd->bkgd", probs.astype(v.dtype), v)
    return out.reshape(b, nq, hd)


def _attn_proj_qkv(p: Tree, x: jax.Array, cfg: ModelConfig, sfx: str = ""):
    q = x @ p[f"wq{sfx}"]
    k = x @ p[f"wk{sfx}"]
    v = x @ p[f"wv{sfx}"]
    if f"bq{sfx}" in p:
        q = q + p[f"bq{sfx}"]
        k = k + p[f"bk{sfx}"]
        v = v + p[f"bv{sfx}"]
    return q, k, v


def attn_sublayer_seq(p: Tree, h: jax.Array, cfg: ModelConfig, *,
                      causal: bool, positions: jax.Array,
                      window: Optional[int], use_rope: bool = True,
                      return_kv: bool = False,
                      prefix_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                      prefix_len=None,
                      q_valid: Optional[jax.Array] = None):
    """``prefix_kv`` = (k, v) each (b, P, kv_dim), a reused prefix
    KVCache already roped at its absolute positions and right-padded to
    the static prefix bucket P; ``prefix_len`` (traced scalar, defaults
    to P) is the real prefix length — padded prefix keys are masked out
    of attention, so queries run at absolute offset ``prefix_len`` over
    prefix ++ fresh keys. ``q_valid`` (b,) masks right-pad bucket
    queries (they attend to nothing). ``return_kv`` yields only the
    freshly computed (suffix) k/v."""
    x = rmsnorm(h, p["norm"], cfg.norm_eps)
    q, k, v = _attn_proj_qkv(p, x, cfg)
    q = _split_heads(q, cfg.num_heads)
    k = _split_heads(k, cfg.num_kv_heads)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    v4 = _split_heads(v, cfg.num_kv_heads)
    k_all, v_all, q_off, p_pad = k, v4, 0, None
    if prefix_kv is not None:
        kp, vp = prefix_kv
        p_pad = kp.shape[1]
        q_off = p_pad if prefix_len is None else prefix_len
        k_all = jnp.concatenate(
            [_split_heads(kp.astype(k.dtype), cfg.num_kv_heads), k], axis=1)
        v_all = jnp.concatenate(
            [_split_heads(vp.astype(v4.dtype), cfg.num_kv_heads), v4], axis=1)
    o = attention_seq(q, k_all, v_all, cfg.num_kv_heads, causal=causal,
                      window=window, q_offset=q_off, prefix_pad=p_pad,
                      q_valid=q_valid)
    h = h + _merge_heads(o) @ p["wo"]
    if return_kv:
        return h, (_merge_heads(k), v)
    return h


def cross_attn_seq(p: Tree, h: jax.Array, enc_out: jax.Array,
                   cfg: ModelConfig, *, return_kv: bool = False):
    x = rmsnorm(h, p["norm_x"], cfg.norm_eps)
    q = _split_heads(x @ p["wqx"], cfg.num_heads)
    k = _split_heads(enc_out @ p["wkx"], cfg.num_kv_heads)
    v = _split_heads(enc_out @ p["wvx"], cfg.num_kv_heads)
    o = attention_seq(q, k, v, cfg.num_kv_heads, causal=False, window=None)
    h = h + _merge_heads(o) @ p["wox"]
    if return_kv:
        return h, (_merge_heads(k), _merge_heads(v))
    return h


# ---------------------------------------------------------------- mlp / moe

def mlp(p: Tree, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


MOE_TOKEN_CHUNK = 32768


def moe_ffn(p: Tree, x: jax.Array, cfg: ModelConfig, rows: int = 1,
            valid: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """Capacity-based top-k MoE with scatter dispatch, chunked over tokens.

    x: (T, d). Returns (y (T, d), aux_loss scalar). Long sequences are
    processed in token chunks (scan) so dispatch/one-hot/expert buffers stay
    bounded — unchunked, the 1M-token deepseek prefill needs ~100GiB/device
    of dispatch state.

    ``rows`` > 1 marks x as ``rows`` independent batch rows of T//rows
    tokens each: capacity dispatch then counts expert positions PER ROW,
    so a request's outputs never depend on what it happens to be batched
    with (batch-invariance — the engine-vs-oracle contract for serving,
    where the oracle decodes each request alone).

    ``valid`` (rows,) marks how many leading tokens of each row are real
    prompt tokens (right-pad bucketing): padded tokens are force-routed
    to a null capacity slot — they consume no expert capacity and
    receive zero expert output — so bucket padding can never change a
    real token's routing (see _moe_dispatch_capacity).
    """
    T, d = x.shape
    if T > MOE_TOKEN_CHUNK:
        if rows > 1:
            # rows are independent by construction: scan row-by-row so
            # only one row's dispatch state is live, and recurse with
            # rows=1 so an over-long row still chunks internally
            x3 = x.reshape(rows, T // rows, d)
            valid_r = jnp.full((rows,), T // rows, jnp.int32) \
                if valid is None else jnp.asarray(valid, jnp.int32)

            @jax.checkpoint
            def rbody(acc, xs):
                xr, vr = xs
                yr, aux = moe_ffn(p, xr, cfg, valid=vr[None])
                return acc + aux, yr

            aux, ys = lax.scan(rbody, jnp.zeros((), jnp.float32),
                               (x3, valid_r))
            return ys.reshape(T, d), aux / rows
        # chunk boundaries must align with the capacity window so
        # window-local slot counting never straddles a scan step; when
        # no aligned divisor of T exists, pad the row up to whole
        # aligned chunks instead (pad tokens are invalid -> null slot,
        # outputs sliced off) — never silently misalign the windows
        W = cfg.moe.capacity_window if cfg.moe.dispatch == "capacity" else 1
        assert W <= MOE_TOKEN_CHUNK, (W, MOE_TOKEN_CHUNK)
        divs = [c for c in range(1, MOE_TOKEN_CHUNK + 1)
                if T % c == 0 and c % W == 0]
        if divs:
            chunk, T_pad = max(divs), T
        else:
            chunk = MOE_TOKEN_CHUNK - MOE_TOKEN_CHUNK % W
            T_pad = -(-T // chunk) * chunk
        nc = T_pad // chunk
        xp = x if T_pad == T else jnp.pad(x, ((0, T_pad - T), (0, 0)))
        x3 = xp.reshape(nc, chunk, d)
        v_scalar = jnp.asarray(T if valid is None else valid,
                               jnp.int32).reshape(())
        v_chunks = jnp.clip(v_scalar - jnp.arange(nc) * chunk, 0, chunk)

        @jax.checkpoint
        def body(acc, xs):
            xc, vc = xs
            yc, aux = _moe_dispatch(p, xc, cfg, valid=vc[None])
            return acc + aux, yc

        aux, ys = lax.scan(body, jnp.zeros((), jnp.float32),
                           (x3, v_chunks))
        return ys.reshape(T_pad, d)[:T], aux / nc
    return _moe_dispatch(p, x, cfg, rows, valid)


def _moe_dispatch(p: Tree, x: jax.Array, cfg: ModelConfig, rows: int = 1,
                  valid: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    if cfg.moe.dispatch == "sorted":
        # dropless dispatch is per-token (no capacity competition):
        # padded rows route like any token but their outputs are sliced
        # off by the caller — already pad-invariant, no mask needed
        return _moe_dispatch_sorted(p, x, cfg)
    return _moe_dispatch_capacity(p, x, cfg, rows, valid)


def _moe_router(p: Tree, x: jax.Array, cfg: ModelConfig):
    m = cfg.moe
    logits = (x @ p["router"]).astype(jnp.float32)        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = lax.top_k(probs, m.top_k)                # (T, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(idx.reshape(-1), m.num_experts, dtype=jnp.int32)
    frac = jnp.mean(onehot.astype(jnp.float32), axis=0)
    aux = m.num_experts * jnp.sum(frac * jnp.mean(probs, axis=0)) \
        * m.router_aux_coef
    return gates, idx, aux


def _moe_dispatch_sorted(p: Tree, x: jax.Array, cfg: ModelConfig
                         ) -> Tuple[jax.Array, jax.Array]:
    """Dropless sort-based dispatch (Megablocks-style): tokens are sorted
    by expert and each expert consumes a contiguous ragged segment via
    lax.ragged_dot — the capacity scatter (which GSPMD lowers to a whole-
    buffer all-reduce, §Perf E2) disappears entirely."""
    m = cfg.moe
    T, d = x.shape
    E, K = m.num_experts, m.top_k
    gates, idx, aux = _moe_router(p, x, cfg)
    flat_e = idx.reshape(-1)                              # (T*K,) token-major
    order = jnp.argsort(flat_e)
    x_kt = jnp.repeat(x, K, axis=0)                       # (T*K, d)
    xs = jnp.take(x_kt, order, axis=0)
    group_sizes = jnp.bincount(flat_e, length=E).astype(jnp.int32)
    h = jax.nn.silu(lax.ragged_dot(xs, p["w_gate"], group_sizes)) * \
        lax.ragged_dot(xs, p["w_up"], group_sizes)
    ys = lax.ragged_dot(h, p["w_down"], group_sizes)
    y_kt = jnp.zeros_like(x_kt).at[order].set(ys)
    y = (y_kt * gates.reshape(-1)[:, None].astype(ys.dtype)) \
        .reshape(T, K, d).sum(1)
    if m.num_shared_experts:
        y = y + mlp(p["shared"], x)
    return y, aux


def _moe_dispatch_capacity(p: Tree, x: jax.Array, cfg: ModelConfig,
                           rows: int = 1,
                           valid: Optional[jax.Array] = None
                           ) -> Tuple[jax.Array, jax.Array]:
    """GShard-style capacity scatter, window-local and pad-invariant.

    Expert capacity is counted inside fixed windows of
    ``cfg.moe.capacity_window`` consecutive tokens per row (aligned to
    the row start), never across the whole row: the static slot buffer
    holds ceil(W*K/E*cf) slots per (window, expert), while the keep
    threshold for each window is computed from the window's VALID token
    count — the same value an exact-length run computes — so the rule is
    row-length-independent:

      * right-pad invariance: padded tokens (``valid`` (rows,) marks the
        real per-row token counts) are force-routed to the null slot —
        they consume no capacity, receive zero expert output, and leave
        every real token's window population and threshold untouched;
      * prefix transparency: a suffix-only prefill whose prefix length
        is a multiple of W (the engine aligns prefix hits) sees exactly
        the windows the full run gives its suffix tokens, so capacity
        competition never crosses the reuse boundary;
      * batch invariance (as before): windows are within-row, so
        co-batched rows cannot shift which tokens overflow.

    With rows == 1 and no padding the math is the window-chunked
    analogue of the original whole-row counting (single-row callers
    remain batch-size independent).
    """
    m = cfg.moe
    T, d = x.shape
    E, K = m.num_experts, m.top_k
    R = max(1, rows)
    assert T % R == 0, (T, R)
    s = T // R
    # effective window: a row shorter than the configured window IS its
    # own (single) window — routing-identical to padding it out to W
    # (same valid-assignment order, same valid-count threshold), but the
    # one-token decode step keeps its original slot buffer instead of
    # paying W x padding FLOPs inside the fused hot loop
    W = min(m.capacity_window, s)
    nw = -(-s // W)
    s_pad = nw * W
    G = R * nw                                            # capacity windows
    C = max(1, int(math.ceil(W * K / E * m.capacity_factor)))

    if valid is None:
        valid_r = jnp.full((R,), s, jnp.int32)
    else:
        valid_r = jnp.asarray(valid, jnp.int32).reshape(R)

    logits = (x @ p["router"]).astype(jnp.float32)        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = lax.top_k(probs, K)                      # (T, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    vmask = jnp.arange(s_pad)[None, :] < valid_r[:, None]  # (R, s_pad)

    def padrow(t):                      # (T, ...) -> (R, s_pad, ...)
        t = t.reshape((R, s) + t.shape[1:])
        if s_pad != s:
            widths = [(0, 0)] * t.ndim
            widths[1] = (0, s_pad - s)
            t = jnp.pad(t, widths)
        return t

    # per-window choice-major flattening: within each window, all first
    # choices, then all second choices...
    flat_e = jnp.swapaxes(padrow(idx).reshape(G, W, K), 1, 2) \
        .reshape(G, K * W)
    vm_w = vmask.reshape(G, W)
    vflat = jnp.tile(vm_w[:, None, :], (1, K, 1)).reshape(G, K * W)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32) \
        * vflat[..., None].astype(jnp.int32)              # (G, K*W, E)
    pos_in_e = (jnp.cumsum(onehot, axis=1) - 1)           # (G, K*W, E)
    pos_tok = jnp.take_along_axis(pos_in_e, flat_e[..., None],
                                  axis=2)[..., 0]         # (G, K*W)
    # keep threshold from the window's valid token count (traced): the
    # exact-length run evaluates the identical expression, so bucket
    # padding can never change which tokens overflow
    n_valid_w = vm_w.sum(axis=1).astype(jnp.float32)      # (G,)
    c_thr = jnp.ceil(n_valid_w * (K * m.capacity_factor / E)) \
        .astype(jnp.int32)
    # the f32 ceil can land one above the f64-derived buffer capacity C
    # when W*K*cf/E is an exact integer — clamp, or a kept token's slot
    # would alias the next expert's slot 0
    c_thr = jnp.minimum(c_thr, C)
    keep = vflat & (pos_tok < c_thr[:, None])
    grp_base = (jnp.arange(G) * E * C)[:, None]
    slot = jnp.where(keep, grp_base + flat_e * C + pos_tok,
                     G * E * C)            # overflow AND pads -> null slot
    slot = slot.reshape(-1)
    keep = keep.reshape(-1)

    # (G, K*W, d) rows of x in the same per-window choice-major order
    x_kt = jnp.tile(padrow(x).reshape(G, W, d), (1, K, 1)) \
        .reshape(G * K * W, d)
    buf = jnp.zeros((G * E * C + 1, d), x.dtype).at[slot].add(x_kt)
    xe = buf[: G * E * C].reshape(G, E, C, d)
    # canonical EP layout under *_ep act rules (no-op otherwise): expert
    # dim on `model`, capacity on `data` -> expert matmuls are local and
    # only the token<->capacity resharding (all-to-all) moves data.
    xe = jnp.moveaxis(xe, 0, 1).reshape(E, G * C, d)
    xe = constrain(xe, ("expert_act", "cap_act", None))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    h = constrain(h, ("expert_act", "cap_act", None))
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    ye = constrain(ye, ("expert_act", "cap_act", None))
    ye = jnp.moveaxis(ye.reshape(E, G, C, d), 0, 1).reshape(G * E * C, d)
    ye = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)], axis=0)
    y_kt = ye[slot] * keep[:, None].astype(ye.dtype)
    gates_kt = jnp.swapaxes(padrow(gates).reshape(G, W, K), 1, 2) \
        .reshape(-1)
    y = (y_kt * gates_kt[:, None].astype(ye.dtype)) \
        .reshape(G, K, W, d).sum(1).reshape(R, s_pad, d)[:, :s] \
        .reshape(T, d)

    if m.num_shared_experts:
        y = y + mlp(p["shared"], x)    # per-token: pad rows sliced upstream

    # load-balance aux loss (Switch-style) over VALID assignments only,
    # at the ORIGINAL scale (per-row assignment counts, not a
    # normalized fraction — router_aux_coef was tuned against it)
    counts = onehot.astype(jnp.float32).sum((0, 1)) / R           # (E,)
    vtok = vmask[:, :s].reshape(T).astype(jnp.float32)
    mean_p = (probs * vtok[:, None]).sum(0) / jnp.maximum(vtok.sum(), 1.0)
    aux = E * jnp.sum(counts * mean_p) * m.router_aux_coef
    return y, aux


# ---------------------------------------------------------------- mamba2 ssd

def _causal_conv1d(x: jax.Array, w: jax.Array,
                   init: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv. x: (b, s, c); w: (c, k); init: (b, c, k-1)."""
    b, s, c = x.shape
    k = w.shape[1]
    if init is None:
        pad = jnp.zeros((b, k - 1, c), x.dtype)
    else:
        pad = jnp.swapaxes(init, 1, 2)  # (b, k-1, c)
    xp = jnp.concatenate([pad, x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i:i + s, :] * w[:, i]
    return out


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, chunk: int,
             init_state: Optional[jax.Array] = None,
             return_chunk_states: bool = False):
    """Chunked SSD (Mamba2, arXiv:2405.21060 listing 1), n_groups == 1.

    x: (b, s, nh, hd); dt: (b, s, nh); A: (nh,); B, C: (b, s, n).
    Returns y (b, s, nh, hd) and final state (b, nh, n, hd); with
    ``return_chunk_states`` also the per-chunk carried states
    (nc, b, nh, n, hd) — chunk_states[m] is the state after chunk m,
    i.e. bitwise the final state of a run truncated at (m+1)*chunk
    tokens (the chunk partition is config-fixed, so the carries ARE
    exact boundary snapshots; see mamba_sublayer_seq snap_stride).

    The sequence is right-padded up to a whole number of chunks with
    dt == 0 rows: a zero-dt token neither decays nor updates the carried
    state (exp(0) == 1, zero write weight), so the chunk PARTITION of a
    length-s run is a pure function of ceil(s/chunk) — two runs whose
    valid tokens agree produce the same final state even when their
    padded lengths differ (the masked tail chunks are state no-ops).
    This is what makes the recurrent state of a bucket-padded prefill
    identical to the exact-length run (callers mask dt for their own
    right-pad tokens; see mamba_sublayer_seq).
    """
    b, s, nh, hd = x.shape
    n = B.shape[-1]
    # chunk must be a function of the CONFIG only (never of s): two runs
    # of different padded lengths must partition their common valid
    # prefix into identical chunks for the state to match bitwise
    nc = -(-s // chunk)
    s_pad = nc * chunk

    def resh(t):
        if s_pad != s:
            widths = [(0, 0)] * t.ndim
            widths[1] = (0, s_pad - s)
            t = jnp.pad(t, widths)       # zero x/B/C and — crucially — dt
        return jnp.moveaxis(t.reshape((b, nc, chunk) + t.shape[2:]), 1, 0)

    xs, dts, Bs, Cs = resh(x), resh(dt), resh(B), resh(C)
    if init_state is None:
        init_state = jnp.zeros((b, nh, n, hd), jnp.float32)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    @jax.checkpoint
    def body(S, inp):
        xc, dtc, Bc, Cc = inp          # (b,Q,nh,hd), (b,Q,nh), (b,Q,n), (b,Q,n)
        da = dtc * A                   # (b,Q,nh)  (A negative)
        cs = jnp.cumsum(da, axis=1)    # (b,Q,nh)
        # intra-chunk
        seg = jnp.exp(cs[:, :, None, :] - cs[:, None, :, :])       # (b,Q,K,nh)
        seg = jnp.where(causal[None, :, :, None], seg, 0.0)
        cb = jnp.einsum("bqn,bkn->bqk", Cc, Bc,
                        preferred_element_type=jnp.float32)
        att = cb[..., None] * seg * dtc[:, None, :, :]             # (b,Q,K,nh)
        y = jnp.einsum("bqkh,bkhp->bqhp", att, xc,
                       preferred_element_type=jnp.float32)
        # inter-chunk: contribution of carried state
        y = y + jnp.einsum("bqn,bhnp->bqhp", Cc, S,
                           preferred_element_type=jnp.float32) \
            * jnp.exp(cs)[..., None]
        # state update
        total = cs[:, -1, :]                                        # (b,nh)
        w_k = jnp.exp(total[:, None, :] - cs) * dtc                 # (b,Q,nh)
        dS = jnp.einsum("bkn,bkh,bkhp->bhnp", Bc, w_k, xc,
                        preferred_element_type=jnp.float32)
        S = S * jnp.exp(total)[:, :, None, None] + dS
        return S, (y.astype(x.dtype), S) if return_chunk_states \
            else y.astype(x.dtype)

    S, ys = lax.scan(body, init_state, (xs, dts, Bs, Cs))
    if return_chunk_states:
        ys, chunk_states = ys
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s_pad, nh, hd)[:, :s]
    if return_chunk_states:
        return y, S, chunk_states
    return y, S


def ssd_step(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single-token SSD recurrence. x: (b,nh,hd); dt: (b,nh); B,C: (b,n);
    state: (b,nh,n,hd)."""
    da = jnp.exp(dt * A)                                        # (b,nh)
    dS = jnp.einsum("bn,bh,bhp->bhnp", B, dt, x,
                    preferred_element_type=jnp.float32)
    state = state * da[:, :, None, None] + dS
    y = jnp.einsum("bn,bhnp->bhp", C, state,
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype), state


def mamba_sublayer_seq(p: Tree, h: jax.Array, cfg: ModelConfig, *,
                       return_state: bool = False,
                       valid_len: Optional[jax.Array] = None,
                       init: Optional[Tree] = None,
                       snap_stride: int = 0):
    """``valid_len`` (b,) marks the real (un-padded) token count per row
    of a right-pad-bucketed batch. Padded tokens are masked out of the
    recurrence by zeroing their dt AFTER the softplus — a zero-dt token
    neither decays nor writes the SSD state (see ssd_scan) — and the
    conv tails returned for decode hand-off are gathered at each row's
    valid boundary, not the padded end. The causal conv itself is
    right-pad-inert (outputs at valid positions never read later
    positions), so the forward at valid positions and the final
    recurrent state are identical to the exact-length run.

    ``init`` restores a boundary snapshot {"conv_x","conv_b","conv_c"
    (b,c,k-1), "state" (b,nh,n,hd)}: the conv windows are seeded with
    the last k-1 pre-conv inputs of the cached prefix and the SSD scan
    starts from the carried state, so a suffix-only run continues the
    recurrence bitwise (the restore boundary is a multiple of the SSD
    chunk, keeping the suffix chunk partition aligned with the cold
    run's). ``snap_stride`` > 0 (static; a multiple of the SSD chunk)
    additionally emits snapshots at every stride boundary t of THIS
    run: "snap_state" (nb,b,nh,n,hd) from the per-chunk scan carries
    and "snap_conv_{x,b,c}" (nb,b,c,k-1) static input slices — bitwise
    the state/conv tail a run truncated at t would hand to decode."""
    s_cfg = cfg.ssm_cfg
    d_in = s_cfg.expand * cfg.d_model
    nh = d_in // s_cfg.head_dim
    s = h.shape[1]
    x = rmsnorm(h, p["norm"], cfg.norm_eps)
    z = x @ p["w_z"]
    xin = x @ p["w_x"]
    bin_ = x @ p["w_b"]
    cin = x @ p["w_c"]
    dt = x @ p["w_dt"] + p["dt_bias"]
    ini = init or {}
    xc = jax.nn.silu(_causal_conv1d(xin, p["conv_x"], ini.get("conv_x")))
    bc = jax.nn.silu(_causal_conv1d(bin_, p["conv_b"], ini.get("conv_b")))
    cc = jax.nn.silu(_causal_conv1d(cin, p["conv_c"], ini.get("conv_c")))
    dt = jax.nn.softplus(dt.astype(jnp.float32))
    if valid_len is not None:
        vmask = jnp.arange(s)[None, :] < valid_len[:, None]    # (b, s)
        dt = jnp.where(vmask[..., None], dt, 0.0)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    x4 = constrain(_split_heads(xc, nh), ("batch", None, "q_heads_act", None))
    dt = constrain(dt, ("batch", None, "q_heads_act"))
    if snap_stride:
        assert snap_stride % s_cfg.chunk == 0, (snap_stride, s_cfg.chunk)
        y4, state, chunk_states = ssd_scan(
            x4, dt, A, bc, cc, s_cfg.chunk, init_state=ini.get("state"),
            return_chunk_states=True)
    else:
        y4, state = ssd_scan(x4, dt, A, bc, cc, s_cfg.chunk,
                             init_state=ini.get("state"))
    y4 = y4 + x4 * p["d_skip"][:, None].astype(x4.dtype)
    y = _merge_heads(y4)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_g"], cfg.norm_eps)
    out = h + y @ p["w_out"]
    if return_state:
        k = s_cfg.conv_kernel

        def tail(t, ikey):              # (b, s, c) -> (b, c, k-1)
            if init is not None:
                # the conv window may span the restore boundary when the
                # suffix is shorter than k-1: gather from the snapshot
                # tail ++ this run's inputs (all positions real)
                ext = jnp.concatenate(
                    [jnp.swapaxes(ini[ikey], 1, 2), t], axis=1)
                vl = valid_len[:, None] if valid_len is not None \
                    else jnp.full((t.shape[0], 1), s, jnp.int32)
                idx = vl + jnp.arange(k - 1)[None]
                g = jnp.take_along_axis(ext, idx[..., None], axis=1)
                return jnp.swapaxes(g, 1, 2)
            if valid_len is None:
                return jnp.swapaxes(t[:, -(k - 1):, :], 1, 2)
            # last k-1 VALID inputs per row (zeros left of the sequence
            # start, exactly what _causal_conv1d pads with)
            idx = valid_len[:, None] - (k - 1) + jnp.arange(k - 1)[None]
            g = jnp.take_along_axis(t, jnp.clip(idx, 0, s - 1)[..., None],
                                    axis=1)
            g = jnp.where((idx >= 0)[..., None], g,
                          jnp.zeros((), t.dtype))
            return jnp.swapaxes(g, 1, 2)

        tails = {
            "conv_x": tail(xin, "conv_x"),
            "conv_b": tail(bin_, "conv_b"),
            "conv_c": tail(cin, "conv_c"),
            "state": state,
        }
        if snap_stride:
            # boundary j (1-based) sits after j*stride tokens of this
            # run: SSD state = carry after chunk j*stride/chunk - 1,
            # conv tails = the k-1 inputs just before the boundary
            # (stride >= chunk > k-1, so the slices are static and
            # in-range). Boundaries past a row's valid_len hold frozen
            # (zero-dt) state and pad-garbage conv rows — the engine
            # stores only boundaries <= prompt_len.
            nb = s // snap_stride
            bidx = [(j + 1) * snap_stride for j in range(nb)]
            if nb:
                tails["snap_state"] = jnp.stack(
                    [chunk_states[t // s_cfg.chunk - 1] for t in bidx])
                for key, t in (("snap_conv_x", xin), ("snap_conv_b", bin_),
                               ("snap_conv_c", cin)):
                    tails[key] = jnp.stack(
                        [jnp.swapaxes(t[:, b - (k - 1):b], 1, 2)
                         for b in bidx])
            else:
                b = h.shape[0]
                n = p["w_b"].shape[1]
                tails["snap_state"] = jnp.zeros(
                    (0, b, nh, n, s_cfg.head_dim), jnp.float32)
                for key, src in (("snap_conv_x", xin), ("snap_conv_b", bin_),
                                 ("snap_conv_c", cin)):
                    tails[key] = jnp.zeros(
                        (0, b, src.shape[-1], k - 1), src.dtype)
        return out, tails
    return out


def mamba_sublayer_step(p: Tree, h: jax.Array, cache: Tree,
                        cfg: ModelConfig) -> Tuple[jax.Array, Tree]:
    """One-token mamba step. h: (b, d). cache leaves unstacked."""
    s_cfg = cfg.ssm_cfg
    d_in = s_cfg.expand * cfg.d_model
    nh = d_in // s_cfg.head_dim
    x = rmsnorm(h, p["norm"], cfg.norm_eps)
    z = x @ p["w_z"]
    xin = x @ p["w_x"]
    bin_ = x @ p["w_b"]
    cin = x @ p["w_c"]
    dt = x @ p["w_dt"] + p["dt_bias"]

    def conv_step(state, new, w):
        win = jnp.concatenate([state, new[:, :, None]], axis=2)  # (b,c,k)
        out = jnp.sum(win * w[None], axis=2)
        return out, win[:, :, 1:]

    xc, cx = conv_step(cache["conv_x"], xin, p["conv_x"])
    bc, cb = conv_step(cache["conv_b"], bin_, p["conv_b"])
    cc, ccs = conv_step(cache["conv_c"], cin, p["conv_c"])
    xc, bc, cc = jax.nn.silu(xc), jax.nn.silu(bc), jax.nn.silu(cc)
    dt = jax.nn.softplus(dt.astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    x3 = xc.reshape(-1, nh, s_cfg.head_dim)
    y3, state = ssd_step(x3, dt, A, bc, cc, cache["state"])
    y3 = y3 + x3 * p["d_skip"][:, None].astype(x3.dtype)
    y = y3.reshape(-1, d_in)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_g"], cfg.norm_eps)
    out = h + y @ p["w_out"]
    return out, {"conv_x": cx, "conv_b": cb, "conv_c": ccs,
                 "state": state}


# ---------------------------------------------------------------- blocks

def _ffn_sublayer(p: Tree, h: jax.Array, cfg: ModelConfig, is_moe: bool,
                  valid_len: Optional[jax.Array] = None):
    aux = jnp.zeros((), jnp.float32)
    if is_moe:
        x = rmsnorm(h, p["norm2"], cfg.norm_eps)
        shp = x.shape
        # batch rows are independent requests: capacity dispatch must
        # count expert slots per row (batch-invariant serving), with
        # right-pad bucket tokens routed to the null slot (valid_len)
        y, aux = moe_ffn(p["moe"], x.reshape(-1, shp[-1]), cfg,
                         rows=shp[0] if len(shp) == 3 else 1,
                         valid=valid_len if len(shp) == 3 else None)
        h = h + y.reshape(shp)
    elif cfg.d_ff > 0:
        h = h + mlp(p["mlp"], rmsnorm(h, p["norm2"], cfg.norm_eps))
    return h, aux


def block_seq(cfg: ModelConfig, blk_params: Tree, h: jax.Array, *,
              positions: jax.Array, causal: bool,
              window: Optional[int], enc_out: Optional[jax.Array],
              collect_cache: bool,
              prefix: Optional[Tree] = None,
              prefix_len=None,
              valid_len: Optional[jax.Array] = None,
              ssm_state: Optional[Tree] = None,
              snap_stride: int = 0
              ) -> Tuple[jax.Array, jax.Array, Tree]:
    """Apply one repeating block (period sublayers). Returns (h, aux, cache).

    ``prefix`` maps "sub{i}" -> {"k", "v"} reused prefix KVCaches
    (b, P, kv_dim) for this block's attention sublayers, right-padded to
    the static prefix bucket P with only the first ``prefix_len``
    (traced) rows real; mamba sublayers carry no entry (or an empty
    one) — their prefix restore rides in ``ssm_state``, which maps
    "sub{i}" -> boundary snapshot {"conv_x","conv_b","conv_c","state"}
    seeding the sublayer's conv windows and SSD scan (see
    mamba_sublayer_seq). ``snap_stride`` > 0 makes mamba sublayers also
    EMIT snapshots at stride boundaries into the cache. ``valid_len``
    (b,) marks real suffix tokens of a right-pad-bucketed batch — the
    pad-invariance contract every sublayer honors (masked attention
    queries, zero-dt SSD recurrence, null-slot MoE capacity)."""
    kinds = cfg.layer_kinds()
    moe_mask = cfg.moe_layer_mask()
    period = block_period(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    cache_out: Tree = {}
    use_rope = True  # decoder self-attn always uses RoPE (whisper deviation noted)
    for i in range(period):
        p = blk_params[f"sub{i}"]
        c: Tree = {}
        if kinds[i] == ATTN:
            pfx = None
            if prefix is not None:
                pc = prefix.get(f"sub{i}")
                if pc:
                    pfx = (pc["k"], pc["v"])
            if collect_cache:
                h, (k, v) = attn_sublayer_seq(
                    p, h, cfg, causal=causal, positions=positions,
                    window=window, use_rope=use_rope, return_kv=True,
                    prefix_kv=pfx, prefix_len=prefix_len,
                    q_valid=valid_len)
                c["k"], c["v"] = k, v
            else:
                h = attn_sublayer_seq(p, h, cfg, causal=causal,
                                      positions=positions, window=window,
                                      use_rope=use_rope, prefix_kv=pfx,
                                      prefix_len=prefix_len,
                                      q_valid=valid_len)
        else:
            ini = None
            if ssm_state is not None:
                si = ssm_state.get(f"sub{i}")
                if si:
                    ini = si
            if collect_cache:
                h, tails = mamba_sublayer_seq(p, h, cfg, return_state=True,
                                              valid_len=valid_len,
                                              init=ini,
                                              snap_stride=snap_stride)
                c.update(tails)
            else:
                h = mamba_sublayer_seq(p, h, cfg, valid_len=valid_len,
                                       init=ini)
        if enc_out is not None:
            if collect_cache:
                h, (xk, xv) = cross_attn_seq(p, h, enc_out, cfg, return_kv=True)
                c["xk"], c["xv"] = xk, xv
            else:
                h = cross_attn_seq(p, h, enc_out, cfg)
        h, aux = _ffn_sublayer(p, h, cfg, moe_mask[i], valid_len=valid_len)
        aux_total = aux_total + aux
        cache_out[f"sub{i}"] = c
    return h, aux_total, cache_out


def block_decode(cfg: ModelConfig, blk_params: Tree, h: jax.Array,
                 layers_cache: Tree, blk_idx: jax.Array, pos: jax.Array, *,
                 window: Optional[int]) -> Tuple[jax.Array, jax.Array, Tree]:
    """One-token step through one repeating block. h: (b, d).

    `layers_cache` holds the FULL stacked cache (leading dim = num_blocks)
    and is updated in place at `blk_idx` — it is threaded through the layer
    scan as a CARRY, so the while loop keeps one aliased buffer and each
    step writes only the one-token slice (scanning the cache as xs/ys
    instead would physically copy the entire cache every decode step —
    observed as ~200GB/step of copies on the 12B decode lowering).
    """
    kinds = cfg.layer_kinds()
    moe_mask = cfg.moe_layer_mask()
    period = block_period(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    use_rope = True
    layers_cache = dict(layers_cache)
    for i in range(period):
        p = blk_params[f"sub{i}"]
        c = dict(layers_cache[f"sub{i}"])
        if kinds[i] == ATTN:
            x = rmsnorm(h, p["norm"], cfg.norm_eps)
            q, k, v = _attn_proj_qkv(p, x[:, None, :], cfg)
            q4 = _split_heads(q[:, 0], cfg.num_heads)
            k4 = _split_heads(k[:, 0], cfg.num_kv_heads)
            if use_rope:
                q4 = rope(q4, pos, cfg.rope_theta)
                k4 = rope(k4, pos, cfg.rope_theta)
            S = c["k"].shape[2]
            slot = (pos % S) if window is not None else pos
            zero = jnp.zeros((), slot.dtype)
            # read the OLD cache slice, attend with the new token passed
            # explicitly, and write the one-token update afterwards — the
            # carried buffer is never read after being written in-body.
            k_cache = lax.dynamic_index_in_dim(c["k"], blk_idx, 0,
                                               keepdims=False)
            v_cache = lax.dynamic_index_in_dim(c["v"], blk_idx, 0,
                                               keepdims=False)
            k_flat = _merge_heads(k4)
            v_flat = v[:, 0]
            o = attention_decode(q4, k_cache, v_cache, cfg.num_kv_heads,
                                 pos, window=window,
                                 k_new=k_flat, v_new=v_flat)
            c["k"] = lax.dynamic_update_slice(
                c["k"], k_flat[None, :, None, :], (blk_idx, zero, slot, zero))
            c["v"] = lax.dynamic_update_slice(
                c["v"], v_flat[None, :, None, :], (blk_idx, zero, slot, zero))
            h = h + _merge_heads(o) @ p["wo"]
        else:
            mc_in = {k2: lax.dynamic_index_in_dim(v2, blk_idx, 0, False)
                     for k2, v2 in c.items() if k2.startswith(("conv", "state"))}
            h, mc = mamba_sublayer_step(p, h, mc_in, cfg)
            for k2, v2 in mc.items():
                c[k2] = lax.dynamic_update_slice_in_dim(
                    c[k2], v2.astype(c[k2].dtype)[None], blk_idx, axis=0)
        if cfg.is_encoder_decoder:
            x = rmsnorm(h, p["norm_x"], cfg.norm_eps)
            q4 = _split_heads(x @ p["wqx"], cfg.num_heads)
            xk = lax.dynamic_index_in_dim(c["xk"], blk_idx, 0, False)
            xv = lax.dynamic_index_in_dim(c["xv"], blk_idx, 0, False)
            o = attention_decode(q4, xk, xv, cfg.num_kv_heads,
                                 jnp.asarray(xk.shape[1]), window=None)
            h = h + _merge_heads(o) @ p["wox"]
        h2, aux = _ffn_sublayer(p, h[:, None, :], cfg, moe_mask[i])
        h = h2[:, 0]
        aux_total = aux_total + aux
        layers_cache[f"sub{i}"] = c
    return h, aux_total, layers_cache


# ---------------------------------------------------------------- encoder

def encoder_forward(cfg: ModelConfig, params: Tree,
                    frames: jax.Array) -> jax.Array:
    """Whisper-style encoder over stub frame embeddings (b, enc_seq, d)."""
    enc = params["encoder"]
    h = frames + enc["pos_embed"].astype(frames.dtype)
    positions = jnp.arange(frames.shape[1])

    @jax.checkpoint
    def body(hh, blkp):
        hh = attn_sublayer_seq(blkp, hh, cfg, causal=False,
                               positions=positions, window=None,
                               use_rope=False)
        hh, _ = _ffn_sublayer(blkp, hh, cfg, False)
        return hh, None

    h, _ = lax.scan(body, h, enc["blocks"]["sub0"])
    return rmsnorm(h, enc["final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------- full fwd

def _embed_inputs(cfg: ModelConfig, params: Tree, batch: Tree) -> jax.Array:
    if "embeds" in batch:          # VLM stub frontend: embeddings come in
        return batch["embeds"]
    return params["embed"][batch["tokens"]].astype(params["embed"].dtype)


def forward_seq(cfg: ModelConfig, params: Tree, batch: Tree, *,
                collect_cache: bool, remat: bool,
                window: Optional[int] = None,
                prefix: Optional[Tree] = None, prefix_len=0,
                valid_len: Optional[jax.Array] = None,
                ssm_init: Optional[Tree] = None,
                snap_stride: int = 0
                ) -> Tuple[jax.Array, jax.Array, Optional[Tree]]:
    """Shared train/prefill path. Returns (hidden (b,s,d), aux, cache|None).

    With ``prefix`` (per-block "sub{i}" -> {"k","v"} stacked like
    params["blocks"]: leading dim num_blocks, then (b, P, kv_dim) with P
    the static prefix bucket), the batch holds only the uncached SUFFIX
    tokens: positions start at ``prefix_len`` (a traced scalar <= P;
    padded prefix rows are masked out of attention) and every attention
    sublayer attends over the reused prefix KVCache ++ the fresh suffix
    keys (suffix-only prefill, paper §2.2.1 prefix reuse on the real
    path). ``ssm_init`` is the recurrent-state half of a warm restore —
    per-block "sub{i}" -> boundary snapshot, stacked like
    params["blocks"] — seeding each mamba sublayer's conv windows and
    SSD state so SSM/hybrid stacks continue the recurrence bitwise from
    the snapshot boundary; ``snap_stride`` > 0 emits such snapshots
    into the cache at stride boundaries (see mamba_sublayer_seq).
    ``valid_len`` (b,) is the pad-invariance mask for right-pad
    length-bucketed batches: tokens at row index >= valid_len[b] attend
    to nothing, leave the SSD recurrence untouched, and take no MoE
    capacity (the shared jitted prefill serves EVERY family from
    O(num_buckets) compiled programs)."""
    h = _embed_inputs(cfg, params, batch)
    s = h.shape[1]
    positions = prefix_len + jnp.arange(s)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = encoder_forward(cfg, params, batch["frames"])

    h = constrain(h, ("batch", "seq_act", None))
    extras = {}
    if prefix is not None:
        extras["prefix"] = prefix
    if ssm_init is not None:
        extras["ssm"] = ssm_init

    def body(carry, xs):
        hh, aux = carry
        blkp, ex = xs if extras else (xs, {})
        hh, a, cache = block_seq(cfg, blkp, hh, positions=positions,
                                 causal=True, window=window, enc_out=enc_out,
                                 collect_cache=collect_cache,
                                 prefix=ex.get("prefix"),
                                 prefix_len=prefix_len if extras else None,
                                 valid_len=valid_len,
                                 ssm_state=ex.get("ssm"),
                                 snap_stride=snap_stride)
        hh = constrain(hh, ("batch", "seq_act", None))
        return (hh, aux + a), cache

    if remat:
        body = jax.checkpoint(body)
    xs = params["blocks"] if not extras else (params["blocks"], extras)
    (h, aux), caches = lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), xs,
    )
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return h, aux, (caches if collect_cache else None)


def lm_logits(cfg: ModelConfig, params: Tree, h: jax.Array) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ w.astype(h.dtype)


def chunked_loss(cfg: ModelConfig, params: Tree, h: jax.Array,
                 labels: jax.Array, chunk: int = 512) -> jax.Array:
    """Cross-entropy over seq chunks — never materializes (b,s,vocab)."""
    b, s, d = h.shape
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if s % chunk != 0 or s <= chunk:
        logits = (h @ w.astype(h.dtype))
        return _xent(logits, labels)
    nc = s // chunk
    hc = jnp.moveaxis(h.reshape(b, nc, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

    @jax.checkpoint
    def body(tot, inp):
        hh, ll = inp
        # NOTE: do NOT seq-shard here — the vocab dim must claim `model`
        # (otherwise the lm_head gradient is gathered to full size).
        hh = constrain(hh, ("batch", None, None))
        logits = constrain(hh @ w.astype(hh.dtype),
                           ("batch", None, "vocab"))
        return tot + _xent(logits, ll) * (chunk / s), None

    tot, _ = lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    return tot


def _xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def forward_train(cfg: ModelConfig, params: Tree, batch: Tree,
                  remat: bool = True) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    h, aux, _ = forward_seq(cfg, params, batch, collect_cache=False,
                            remat=remat)
    loss = chunked_loss(cfg, params, h, batch["labels"])
    return loss + aux, {"lm_loss": loss, "aux_loss": aux}


def forward_prefill(cfg: ModelConfig, params: Tree, batch: Tree,
                    window: Optional[int] = None,
                    last_index: Optional[jax.Array] = None,
                    prefix: Optional[Tree] = None, prefix_len=0,
                    ssm_init: Optional[Tree] = None,
                    snap_stride: int = 0
                    ) -> Tuple[jax.Array, Tree]:
    """Returns (first generated token (b,), decode cache).

    `last_index` (b,) selects each row's final prompt position for ragged
    right-padded batches (default: the last column) AND doubles as the
    pad-invariance mask: rows are treated as valid only up to it, so a
    length-bucketed batch is exact for every family (masked attention
    queries, zero-dt SSD recurrence, null-slot MoE capacity — see
    forward_seq). With `prefix`/`prefix_len`/`ssm_init` (see
    forward_seq) the batch is the uncached suffix only — `prefix_len`
    may be a traced scalar under a bucket-padded prefix — and the
    returned cache covers just those suffix tokens; the caller stitches
    prefix ++ suffix back together. `snap_stride` (static, a multiple
    of the SSD chunk) makes mamba sublayers emit boundary snapshots
    into the cache for the prefix-reuse store."""
    valid_len = None if last_index is None \
        else last_index.astype(jnp.int32) + 1
    h, _, caches = forward_seq(cfg, params, batch, collect_cache=True,
                               remat=False, window=window,
                               prefix=prefix, prefix_len=prefix_len,
                               valid_len=valid_len, ssm_init=ssm_init,
                               snap_stride=snap_stride)
    if last_index is None:
        h_last = h[:, -1, :]
    else:
        h_last = jnp.take_along_axis(
            h, last_index[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    logits = lm_logits(cfg, params, h_last)
    first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    s = h.shape[1]
    cache = {"layers": caches, "pos": jnp.asarray(prefix_len + s, jnp.int32)}
    return first, cache


def _decode_step_core(cfg: ModelConfig, params: Tree, storage: jax.Array,
                      block_tables: jax.Array, tokens: jax.Array,
                      pos: jax.Array, active: jax.Array,
                      slot_layers: Tree, *, block_size: int,
                      caps: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, jax.Array, Tree]:
    """One decode iteration's layer loop: (argmax token, storage',
    slot_layers'). Shared by the plain fused step and every micro-step
    of the speculative propose/verify program. ``caps`` (n_slots,)
    int32, when given, additionally drops pool writes at positions past
    a slot's owned capacity — speculative micro-steps run ``pos + j``
    past the last admitted block, and without the guard the clip-mode
    table lookup would redirect those writes onto the slot's LAST real
    block instead of off the end."""
    from repro.kernels import ops
    bs = block_size
    period = block_period(cfg)
    kinds = cfg.layer_kinds()
    moe_mask = cfg.moe_layer_mask()
    nblk = num_blocks(cfg)
    attn_subs = [i for i in range(period) if kinds[i] == ATTN]
    # global attn-layer row of (blk, sub): layers are periodic, so the
    # row index is linear in the block index for a fixed sub position
    a_per_blk = len(attn_subs)
    attn_rank = {s: r for r, s in enumerate(attn_subs)}
    pool_dtype = storage.dtype

    pos = pos.astype(jnp.int32)
    lens = pos + 1                              # incl. the current token
    # vectorized pool token-write indices: (block, offset) per slot
    tok_blk = jnp.take_along_axis(block_tables, (pos // bs)[:, None],
                                  axis=1, mode="clip")[:, 0]
    # inactive slots (and -1 table pads) write past the pool so the
    # scatter's mode="drop" discards them — negative ids would WRAP
    nb = storage.shape[1]
    ok = active & (tok_blk >= 0)
    if caps is not None:
        ok = ok & (pos < caps.astype(jnp.int32))
    tok_blk = jnp.where(ok, tok_blk, nb)
    tok_off = pos % bs
    h = params["embed"][tokens].astype(jnp.float32)

    def body(carry, xs):
        hh, st, layers = carry
        blkp, blk = xs
        layers = dict(layers)
        for i in range(period):
            p = blkp[f"sub{i}"]
            if kinds[i] == ATTN:
                li = blk * a_per_blk + attn_rank[i]
                x = rmsnorm(hh, p["norm"], cfg.norm_eps)
                q, k, v = _attn_proj_qkv(p, x[:, None, :], cfg)
                q4 = _split_heads(q[:, 0], cfg.num_heads)
                k4 = _split_heads(k[:, 0], cfg.num_kv_heads)
                q4 = rope(q4, pos, cfg.rope_theta)
                k4 = rope(k4, pos, cfg.rope_theta)
                kv_tok = jnp.concatenate(
                    [_merge_heads(k4), v[:, 0]], -1).astype(pool_dtype)
                # write-then-attend, exactly like the eager loop (the
                # new value is read back, so in-place aliasing holds —
                # no old-value hazard on the carried buffer)
                st = st.at[li, tok_blk, tok_off].set(kv_tok, mode="drop")
                page = lax.dynamic_index_in_dim(st, li, 0, keepdims=False)
                o = ops.paged_attention_inline(
                    q4.astype(pool_dtype), page, block_tables, lens)
                hh = hh + _merge_heads(o).astype(hh.dtype) @ p["wo"]
            else:
                c = layers[f"sub{i}"]
                mc_in = {k2: lax.dynamic_index_in_dim(c[k2], blk, 0, False)
                         for k2 in ("conv_x", "conv_b", "conv_c", "state")}
                hh, mc = mamba_sublayer_step(p, hh, mc_in, cfg)
                cn = dict(c)
                for k2, v2 in mc.items():
                    cn[k2] = lax.dynamic_update_slice_in_dim(
                        c[k2], v2.astype(c[k2].dtype)[None], blk, axis=0)
                layers[f"sub{i}"] = cn
            if cfg.is_encoder_decoder:
                c = layers[f"sub{i}"]
                x = rmsnorm(hh, p["norm_x"], cfg.norm_eps)
                q4 = _split_heads(x @ p["wqx"], cfg.num_heads)
                xk = lax.dynamic_index_in_dim(c["xk"], blk, 0, False)
                xv = lax.dynamic_index_in_dim(c["xv"], blk, 0, False)
                o = attention_decode(q4.astype(jnp.float32), xk, xv,
                                     cfg.num_kv_heads,
                                     jnp.asarray(cfg.encoder_seq),
                                     window=None)
                hh = hh + _merge_heads(o).astype(hh.dtype) @ p["wox"]
            h2, _ = _ffn_sublayer(p, hh[:, None, :], cfg, moe_mask[i])
            hh = h2[:, 0]
        return (hh, st, layers), None

    (h, storage, slot_layers), _ = lax.scan(
        body, (h, storage, slot_layers),
        (params["blocks"], jnp.arange(nblk)))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(cfg, params, h)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return nxt, storage, slot_layers


def forward_decode_step(cfg: ModelConfig, params: Tree, storage: jax.Array,
                        block_tables: jax.Array, tokens: jax.Array,
                        pos: jax.Array, active: jax.Array,
                        slot_layers: Tree, *, block_size: int
                        ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                   jax.Array, Tree]:
    """ONE fused decode iteration over a fixed slot set — the whole
    per-token layer loop as a single device program (jitted by
    ``decode_step_jit`` with the paged pool and slot buffers donated, so
    XLA updates them in place instead of copying the pool once per
    attention layer per token, which is what the eager loop pays).

    storage:      (attn_layers|1, NB, BS, W) paged pool (K ++ V packed).
    block_tables: (n_slots, T) int32, -1 padded; T is the engine's
                  power-of-two table bucket (fixed shape between
                  admissions -> no retrace in steady state).
    tokens/pos:   (n_slots,) int32 — last emitted token / tokens so far.
    active:       (n_slots,) bool slot mask. Inactive slots compute
                  garbage rows (row-independent math everywhere,
                  including per-row capacity MoE) and their pool writes
                  are dropped via a -1 block id (scatter mode="drop").
    slot_layers:  {"sub{i}": {...}} per-sublayer slot state stacked on a
                  leading num_blocks axis (mamba conv/state tails,
                  enc-dec cross KV), carried through the layer scan and
                  updated in place at the block index.

    Returns (next_token, new_tokens, new_pos, storage', slot_layers');
    next_token is the on-device argmax — the caller's single host
    transfer per step.
    """
    nxt, storage, slot_layers = _decode_step_core(
        cfg, params, storage, block_tables, tokens, pos.astype(jnp.int32),
        active, slot_layers, block_size=block_size)
    new_tokens = jnp.where(active, nxt, tokens)
    new_pos = pos.astype(jnp.int32) + active.astype(jnp.int32)
    return nxt, new_tokens, new_pos, storage, slot_layers


# The public fused entry: pool storage and slot buffers are DONATED —
# callers must re-adopt the returned arrays (DecodeEngine does). Retraces
# only on a new (cfg, slot count, table bucket, pool shape) combination.
decode_step_jit = partial(jax.jit, static_argnames=("cfg", "block_size"),
                          donate_argnames=("storage", "slot_layers")
                          )(forward_decode_step)


def decode_step_cache_size() -> int:
    """Live compilation-cache entries of the fused decode step (the
    retrace-count guard in tests asserts deltas on this)."""
    return decode_step_jit._cache_size()


def forward_spec_decode_step(cfg: ModelConfig, dcfg: ModelConfig,
                             params: Tree, d_params: Tree,
                             storage: jax.Array, d_storage: jax.Array,
                             block_tables: jax.Array, tokens: jax.Array,
                             pos: jax.Array, active: jax.Array,
                             caps: jax.Array, slot_layers: Tree,
                             d_slot_layers: Tree, *, block_size: int,
                             k: int
                             ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                        jax.Array, jax.Array, Tree, Tree]:
    """ONE fused speculative decode iteration: draft proposes ``k``
    tokens, target verifies all ``k+1`` new positions, and each slot
    commits its longest accepted prefix — draft AND target run inside
    this single donated program, and the per-slot ACCEPTANCE COUNT IS
    DATA (an int32 lane), never shape, so any mix of 1..k+1 tokens
    retiring across slots reuses one compiled executable.

    Layout mirrors the plain step; the extras are:

    d_storage:      draft paged KV riding the TARGET's block tables
                    (same NB/BS grid, draft width). Never rolled back —
                    rows past a slot's committed length are masked by
                    ``lens`` and rewritten before they are ever
                    attended, exactly like this round's own stale rows.
    caps:           (n_slots,) int32 owned capacity in tokens. Micro-
                    step ``j`` runs at ``pos + j`` which may exceed the
                    admitted block span; the cap guard drops those pool
                    writes (see ``_decode_step_core``) and the emission
                    clamp below keeps every committed token inside it.
    d_slot_layers:  the draft's recurrent/cross slot state, carried in
                    the same donated carry as the target's.

    Both models scan k+1 micro-steps over the consumed-token sequence
    ``C = [cur, d_1 .. d_k]`` (micro-step j consumes C[j] at pos+j),
    stacking each micro-step's post-state; per-slot acceptance then
    SELECTS the state at depth ``n_emit-1`` (`take_along_axis` over the
    stack axis) — rollback is a gather, not a replay. Target KV rows
    the slot did NOT commit are restored from a pre-verify gather, so
    the paged pool stays bit-identical to plain greedy decode.

    Returns ``(out, new_tokens, new_pos, storage', d_storage',
    slot_layers', d_slot_layers')`` where ``out`` is one packed
    (n_slots, k+2) int32 matrix — columns 0..k are the target's greedy
    tokens G, column k+1 is the emission count ``n_emit`` — the
    caller's single host transfer retires ``out[s, :out[s, k+1]]``.
    """
    bs = block_size
    ms = tokens.shape[0]
    nb = storage.shape[1]
    pos = pos.astype(jnp.int32)
    caps = caps.astype(jnp.int32)
    has_attn = any(kd == ATTN for kd in cfg.layer_kinds())

    # -- draft: propose k tokens; C[j] is the token micro-step j consumes
    def d_body(carry, j):
        tok, dst, dlay = carry
        nxt, dst, dlay = _decode_step_core(
            dcfg, d_params, dst, block_tables, tok, pos + j, active, dlay,
            block_size=bs, caps=caps)
        return (nxt, dst, dlay), (tok, dlay)

    (_, d_storage, _), (c_toks, d_stack) = lax.scan(
        d_body, (tokens, d_storage, d_slot_layers), jnp.arange(k + 1))

    # -- pre-verify gather of the k+1 candidate pool rows per slot, so
    #    uncommitted writes can be restored bit-exactly afterwards
    offs = pos[:, None] + jnp.arange(k + 1)[None, :]          # (ms, k+1)
    qblk = jnp.take_along_axis(block_tables, offs // bs, axis=1,
                               mode="clip")                   # (ms, k+1)
    off = offs % bs
    if has_attn:
        old = storage[:, jnp.clip(qblk, 0, nb - 1), off]      # (L,ms,k+1,W)

    # -- target: teacher-force the same k+1 positions; G[j] is the
    #    target's greedy token after consuming C[0..j]
    def t_body(carry, xs):
        tok, j = xs
        st, lay = carry
        nxt, st, lay = _decode_step_core(
            cfg, params, st, block_tables, tok, pos + j, active, lay,
            block_size=bs, caps=caps)
        return (st, lay), (nxt, lay)

    (storage, _), (g_toks, t_stack) = lax.scan(
        t_body, (storage, slot_layers), (c_toks, jnp.arange(k + 1)))

    # -- acceptance: longest prefix of draft tokens matching the
    #    target's own greedy stream; the +1 is the correction token on
    #    a rejection / the free bonus token when all k are accepted.
    #    All of this is element-wise int math — acceptance is DATA.
    match = (c_toks[1:] == g_toks[:-1]).astype(jnp.int32)     # (k, ms)
    a = jnp.cumprod(match, axis=0).sum(axis=0)                # (ms,)
    n_emit = jnp.clip(jnp.minimum(a + 1, caps - pos), 1, k + 1)

    # -- restore target pool rows past each slot's commit point (only
    #    rows the verify sweep actually wrote: cap/active/pad guarded)
    if has_attn:
        keep = jnp.arange(k + 1)[None, :] < n_emit[:, None]   # (ms, k+1)
        wrote = active[:, None] & (qblk >= 0) & (offs < caps[:, None])
        restore_blk = jnp.where(wrote & ~keep, qblk, nb)
        storage = storage.at[:, restore_blk, off].set(old, mode="drop")

    # -- per-slot state rollback = gather at depth n_emit-1
    sel = (n_emit - 1).astype(jnp.int32)
    slot_layers = select_slot_state(t_stack, sel)
    d_slot_layers = select_slot_state(d_stack, sel)

    last = jnp.take_along_axis(g_toks, sel[None, :], axis=0)[0]
    new_tokens = jnp.where(active, last, tokens)
    emitted = jnp.where(active, n_emit, 0).astype(jnp.int32)
    new_pos = pos + emitted
    out = jnp.concatenate([g_toks.T, emitted[:, None]],
                          axis=1).astype(jnp.int32)           # (ms, k+2)
    return (out, new_tokens, new_pos, storage, d_storage, slot_layers,
            d_slot_layers)


# Speculative twin of decode_step_jit: BOTH pools and BOTH slot-state
# carries are donated. Acceptance counts are data lanes, so retraces
# happen only on a new (cfg, dcfg, k, slot count, table bucket, pool
# shape) combination — never on how many tokens a step retires.
spec_decode_step_jit = partial(
    jax.jit, static_argnames=("cfg", "dcfg", "block_size", "k"),
    donate_argnames=("storage", "d_storage", "slot_layers",
                     "d_slot_layers"))(forward_spec_decode_step)


def spec_decode_step_cache_size() -> int:
    """Live compilation-cache entries of the fused speculative step
    (retrace-guard tests assert deltas on this)."""
    return spec_decode_step_jit._cache_size()


def forward_decode(cfg: ModelConfig, params: Tree, cache: Tree,
                   tokens: jax.Array, *, window: Optional[int] = None
                   ) -> Tuple[jax.Array, Tree]:
    """One decode step. tokens: (b,) int32. Returns (next (b,), new cache)."""
    pos = cache["pos"]
    h = params["embed"][tokens].astype(params["embed"].dtype)
    h = constrain(h, ("batch", None))
    nblk = num_blocks(cfg)

    def body(carry, xs):
        hh, layers = carry
        blkp, idx = xs
        hh, _, layers = block_decode(cfg, blkp, hh, layers, idx, pos,
                                     window=window)
        hh = constrain(hh, ("batch", None))
        return (hh, layers), None

    (h, new_layers), _ = lax.scan(
        body, (h, cache["layers"]), (params["blocks"], jnp.arange(nblk)))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(cfg, params, h)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return nxt, {"layers": new_layers, "pos": pos + 1}
