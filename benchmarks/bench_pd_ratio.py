"""Paper Fig. 12 + Fig. 13a: P/D mismatch and ratio adjustment.

Sweeps n_p:n_d at fixed total instances; the Eq.1 optimum should beat the
worst fixed ratio by >= 60% E2E throughput (paper's claim)."""
from __future__ import annotations

from benchmarks.common import Row
from repro.configs import get_config
from repro.core.cluster_sim import ClusterSim, SimConfig, run_workload
from repro.core.perf_model import InstanceProfile, optimal_ratio, throughput
from repro.core.profiles import profile_for
from repro.core.requests import Scenario, WorkloadGenerator


def run() -> list:
    rows: list[Row] = []
    prof = profile_for(get_config("pangu-38b"))
    # a decode-heavy scenario (long generations) stresses the ratio
    sc = Scenario("bench/gen", "bench", 1024, 4, 256, 64, 320, 64,
                  slo_ttft=6.0)
    total = 12
    horizon = 90.0

    # analytic Eq.1 optimum from the profiled InstanceProfile
    iprof = InstanceProfile(
        ttft_bs=prof.ttft(4 * 1400, 0), b_p=4, r_pre=0.6,
        tpot_bs=prof.tpot(16), b_d=16, gen_tokens=sc.out_tokens_mean,
        xi=0.02)
    n_p_opt, n_d_opt = optimal_ratio(iprof, total)
    rows.append(("pd_ratio/eq1_optimal_np", n_p_opt,
                 f"of_{total}_instances"))

    results = {}
    for n_p in range(1, total):
        n_d = total - n_p
        gen = WorkloadGenerator([sc], base_rps=60.0, seed=5)
        reqs = gen.arrivals(horizon)
        sim = ClusterSim(SimConfig(profile=prof), n_prefill=n_p,
                         n_decode=n_d, policy="ondemand", seed=4)
        m = run_workload(sim, reqs, horizon + 30)
        results[n_p] = m
    best_np = max(results, key=lambda k: results[k]["throughput_rps"])
    best = results[best_np]
    even = results[total // 2]              # the naive 1:1 deployment
    worst = min(results.values(), key=lambda m: m["throughput_rps"])
    gain = (best["throughput_rps"] / max(even["throughput_rps"], 1e-9)
            - 1) * 100
    gain_worst = (best["throughput_rps"]
                  / max(worst["throughput_rps"], 1e-9) - 1) * 100
    for n_p in sorted(results):
        m = results[n_p]
        rows.append((f"pd_ratio/throughput_{n_p}p{total-n_p}d",
                     m["throughput_rps"],
                     f"phi={m['phi']:.3f},ttft_p50={m['ttft_p50']:.2f}"))
    rows.append(("pd_ratio/best_vs_1to1_gain_pct", gain,
                 f"best={best_np}p(paper:>=60),eq1_said={n_p_opt}p"))
    rows.append(("pd_ratio/best_vs_worst_gain_pct", gain_worst,
                 "blind_ratio_penalty"))
    return rows
