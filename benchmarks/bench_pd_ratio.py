"""Paper Fig. 12 + Fig. 13a: P/D mismatch and ratio adjustment.

Sweeps n_p:n_d at fixed total instances; the Eq.1 optimum should beat the
worst fixed ratio by >= 60% E2E throughput (paper's claim). A second,
real-engine section runs a tidal two-wave workload through the
ClusterFrontend and reports the runtime P<->D role flips the adjuster
performs from the group's own observed queue/TTFT/timing stats."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row
from repro.configs import get_config
from repro.core.cluster_sim import ClusterSim, SimConfig, run_workload
from repro.core.perf_model import InstanceProfile, optimal_ratio, throughput
from repro.core.profiles import profile_for
from repro.core.requests import Scenario, WorkloadGenerator


def run() -> list:
    rows: list[Row] = []
    prof = profile_for(get_config("pangu-38b"))
    # a decode-heavy scenario (long generations) stresses the ratio
    sc = Scenario("bench/gen", "bench", 1024, 4, 256, 64, 320, 64,
                  slo_ttft=6.0)
    total = 12
    horizon = 90.0

    # analytic Eq.1 optimum from the profiled InstanceProfile
    iprof = InstanceProfile(
        ttft_bs=prof.ttft(4 * 1400, 0), b_p=4, r_pre=0.6,
        tpot_bs=prof.tpot(16), b_d=16, gen_tokens=sc.out_tokens_mean,
        xi=0.02)
    n_p_opt, n_d_opt = optimal_ratio(iprof, total)
    rows.append(("pd_ratio/eq1_optimal_np", n_p_opt,
                 f"of_{total}_instances"))

    results = {}
    for n_p in range(1, total):
        n_d = total - n_p
        gen = WorkloadGenerator([sc], base_rps=60.0, seed=5)
        reqs = gen.arrivals(horizon)
        sim = ClusterSim(SimConfig(profile=prof), n_prefill=n_p,
                         n_decode=n_d, policy="ondemand", seed=4)
        m = run_workload(sim, reqs, horizon + 30)
        results[n_p] = m
    best_np = max(results, key=lambda k: results[k]["throughput_rps"])
    best = results[best_np]
    even = results[total // 2]              # the naive 1:1 deployment
    worst = min(results.values(), key=lambda m: m["throughput_rps"])
    gain = (best["throughput_rps"] / max(even["throughput_rps"], 1e-9)
            - 1) * 100
    gain_worst = (best["throughput_rps"]
                  / max(worst["throughput_rps"], 1e-9) - 1) * 100
    for n_p in sorted(results):
        m = results[n_p]
        rows.append((f"pd_ratio/throughput_{n_p}p{total-n_p}d",
                     m["throughput_rps"],
                     f"phi={m['phi']:.3f},ttft_p50={m['ttft_p50']:.2f}"))
    rows.append(("pd_ratio/best_vs_1to1_gain_pct", gain,
                 f"best={best_np}p(paper:>=60),eq1_said={n_p_opt}p"))
    rows.append(("pd_ratio/best_vs_worst_gain_pct", gain_worst,
                 "blind_ratio_penalty"))
    rows.extend(_real_tidal_rows())
    return rows


def _real_tidal_rows() -> list:
    """Runtime ratio adjustment on REAL engines under tidal traffic:
    deploy 3P:1D, send a decode-heavy wave then a prefill-heavy wave,
    and let the adjuster flip idle nodes from the observed profile."""
    from repro.serving.cluster import ServeRequest
    from repro.serving.frontend import ClusterFrontend

    cfg = get_config("granite-3-8b").reduced()
    fe = ClusterFrontend(cfg, topology={"tidal/gen": (3, 1)},
                         adjust_ratio=True, adjust_interval=3)
    g = fe.groups["tidal/gen"]
    rng = np.random.default_rng(0)

    def mk(rid, max_new):
        return ServeRequest(
            rid=rid, scenario="tidal/gen",
            tokens=list(rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(6, 12)))),
            max_new_tokens=max_new)

    # tide in: sparse long-generation traffic (decode-bound) ...
    schedule = {t: mk(t, 10) for t in range(0, 12, 2)}
    # ... tide out: dense short-generation traffic (prefill-bound)
    schedule.update({t: mk(100 + t, 1) for t in range(30, 54, 2)})
    reqs = list(schedule.values())
    ratio_track = [g.ratio]
    for t in range(90):
        if t in schedule:
            fe.submit(schedule[t])
        fe.tick()
        if g.ratio != ratio_track[-1]:
            ratio_track.append(g.ratio)
        if t > 54 and all(r.done for r in reqs):
            break
    kinds = [f[3] for f in g.flips]
    n_p, n_d = g.ratio
    return [
        ("pd_ratio/real_engine_flips", float(len(g.flips)),
         f"P->D={kinds.count('P->D')},D->P={kinds.count('D->P')}"),
        ("pd_ratio/real_engine_final_np", float(n_p),
         f"track={'|'.join(f'{p}:{d}' for p, d in ratio_track)}"),
        ("pd_ratio/real_engine_completed", float(sum(r.done for r in reqs)),
         f"of_{len(reqs)}_tidal_requests"),
    ]
