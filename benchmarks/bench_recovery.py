"""Paper Fig. 13b/c/d: auto workflows — tidal group scaling timeline,
fault detection -> substitute integration, and model-loading (SFS vs SSD)."""
from __future__ import annotations

from benchmarks.common import Row
from repro.core.group import (PDGroup, T_CONNECT, T_HEALTH, T_LOAD_SFS,
                              T_LOAD_SSD)
from repro.core.mlops import MLOps, NodeMonitor
from repro.core.requests import tidal_rate
from repro.core.zookeeper import MetaStore


def run() -> list:
    rows: list[Row] = []
    # Fig 13d: pre-compiled model loading, two storages, two models
    for storage, t_load in (("ssd", T_LOAD_SSD), ("sfs", T_LOAD_SFS)):
        total = T_CONNECT + t_load + T_HEALTH
        rows.append((f"recovery/substitute_ready_{storage}_s", total,
                     "connect+load+health(paper:minutes)"))

    # Fig 13c: fault -> substitute timeline
    meta = MetaStore()
    g = PDGroup("bench/g", "s", meta)
    g.setup(0.0, 4, 8)
    ml = MLOps(meta, NodeMonitor(seed=2, fault_rate_per_hour=0.0))
    rec = ml.recover(1000.0, g, g.members("D")[0], "device_reset")
    rows.append(("recovery/auto_recovery_s", rec.recovery_time,
                 f"ratio_after={g.ratio[0]}:{g.ratio[1]}"))

    # Fig 13b: tidal scaling events over one simulated day
    g2 = PDGroup("bench/tidal", "s", MetaStore())
    g2.setup(0.0, 2, 4)
    ml2 = MLOps(MetaStore())
    events = {"scale_out": 0, "scale_in": 0}
    t = 0.0
    while t < 86400.0:
        act = ml2.auto_scale(t, g2, base_rps=40.0,
                             rps_capacity_per_pair=11.0)
        if act:
            events[act] += 1
        t += 1800.0
    rows.append(("recovery/tidal_scale_out_events", events["scale_out"],
                 f"scale_in={events['scale_in']},peak_rate="
                 f"{tidal_rate(40.0, 43200.0):.1f}rps"))

    # §3.7 disaster recovery: a region fails mid-run, service continues
    from repro.configs import get_config
    from repro.core.cluster_sim import ClusterSim, SimConfig
    from repro.core.profiles import profile_for
    from repro.core.regions import Region, ServiceRouter
    from repro.core.requests import Scenario, WorkloadGenerator
    prof = profile_for(get_config("pangu-38b"))
    sc = Scenario("svc/x", "svc", 512, 2, 128, 32, 64, 16, 3.0)
    regions = [Region(n, {sc.name: ClusterSim(SimConfig(profile=prof),
                                              n_prefill=2, n_decode=4,
                                              policy="ondemand", seed=i)})
               for i, n in enumerate(("region-a", "region-b"))]
    router = ServiceRouter(regions, seed=0)
    gen = WorkloadGenerator([sc], base_rps=10, seed=6)
    m = router.run(gen.arrivals(40.0), 70.0, fail_at=20.0,
                   fail_region="region-a")
    rows.append(("recovery/region_failover_success_pct",
                 m["success_rate"] * 100,
                 f"dropped={m['dropped']},routed={m['routed']}"))
    return rows
