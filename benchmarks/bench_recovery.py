"""Paper Fig. 13b/c/d: auto workflows — tidal group scaling timeline,
fault detection -> substitute integration, and model-loading (SFS vs
SSD) — plus the REAL-ENGINE chaos section: crash a decode node
mid-stream under an open-loop Poisson driver (serving/faults.py),
reporting recovery wall, re-admit prefix-cache hit rate and SLO
attainment with/without the fault. Writes ``BENCH_recovery.json``."""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import Row
from repro.core.group import (PDGroup, T_CONNECT, T_HEALTH, T_LOAD_SFS,
                              T_LOAD_SSD)
from repro.core.mlops import MLOps, NodeMonitor
from repro.core.requests import tidal_rate
from repro.core.zookeeper import MetaStore

ARCH = "granite-3-8b"
TOPOLOGY = {"default": (1, 2)}
N_REQUESTS = 12
MAX_NEW = 6
UTIL = 0.6
SLO_TTFT_X = 3.0
SLO_TPOT_X = 3.0
RECOVER_S = 0.05                    # virtual substitute-ready delay
OUT_JSON = os.environ.get("BENCH_RECOVERY_JSON", "BENCH_recovery.json")


def _real_engine_rows() -> list:
    """Open-loop Poisson arrivals on the real tickless data path; the
    chaos run crash-kills one decode node mid-window and recovers it.
    The DeterministicService model keeps both timelines comparable."""
    import jax

    from repro.configs import get_config
    from repro.models.params import init_params
    from repro.serving.cluster import ServeRequest
    from repro.serving.faults import (DeterministicService, FaultEvent,
                                      FaultPlan)
    from repro.serving.frontend import ClusterFrontend

    cfg = get_config(ARCH).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    svc_model = DeterministicService()
    rng = np.random.default_rng(5)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size,
                                          int(rng.integers(6, 14)))))
               for _ in range(N_REQUESTS + 4)]

    def _mk(plan=None):
        return ClusterFrontend(
            cfg, topology=TOPOLOGY, params=params,
            prefill_kwargs={"batch_size": 1}, service_model=svc_model,
            faults=plan, health_timeout_s=0.05,
            fault_kwargs={"heartbeat_s": 0.02,
                          "recover_delay_s": RECOVER_S})

    # calibrate: one JIT-warm request, then three sequential warm ones
    fe = _mk()
    wreqs = [ServeRequest(rid=1000 + i, tokens=p, max_new_tokens=MAX_NEW)
             for i, p in enumerate(prompts[:4])]
    for req in wreqs:
        fe.run([req])
    svc = float(np.median([r.first_token_t - r.submit_t
                           for r in wreqs[1:]]))
    step = float(np.median([(r.finish_t - r.first_token_t)
                            / (len(r.generated) - 1)
                            for r in wreqs[1:]]))
    rate = UTIL / max(svc, 1e-9)
    offsets = list(np.cumsum(rng.exponential(1.0 / rate, N_REQUESTS)))
    ttft_slo, tpot_slo = SLO_TTFT_X * svc, SLO_TPOT_X * step

    def _drive(plan=None):
        fe = _mk(plan)
        reqs = [ServeRequest(rid=i, tokens=p, max_new_tokens=MAX_NEW)
                for i, p in enumerate(prompts[4:4 + N_REQUESTS])]
        for req, dt in zip(reqs, offsets):
            fe.submit(req, at=dt)
        fe.serve(watch=reqs)
        fe.serve()                     # drain recovery events (reboot)
        served = [r for r in reqs if r.done and not r.shed]
        ttft = [r.first_token_t - r.submit_t for r in served]
        tpot = [(r.finish_t - r.first_token_t) / (len(r.generated) - 1)
                for r in served if len(r.generated) > 1]
        ok = sum(1 for a, b in zip(ttft, tpot)
                 if a <= ttft_slo and b <= tpot_slo)
        stats = fe.transfer_stats()["default"]
        return {
            "served": len(served), "n": len(reqs),
            "slo_attainment": ok / max(len(reqs), 1),
            "ttft_p99_s": float(np.percentile(ttft, 99)) if ttft else 0.0,
            "ledger": {k: v for k, v in stats.items()
                       if k.startswith("ft_")},
        }

    base = _drive()
    # crash one decode node roughly mid-window
    t_crash = float(offsets[N_REQUESTS // 2])
    plan = FaultPlan([FaultEvent(t_crash, "crash", "g0/D0", RECOVER_S)])
    chaos = _drive(plan)
    led = chaos["ledger"]

    report = {
        "arch": ARCH,
        "topology": {k: list(v) for k, v in TOPOLOGY.items()},
        "calibration": {"service_s": svc, "step_s": step, "rate": rate},
        "fault": {"t_crash": t_crash, "target": "g0/D0",
                  "recover_s": RECOVER_S},
        "fault_free": base,
        "chaos": chaos,
    }
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)

    return [
        ("recovery/real_slo_attainment_clean_pct",
         base["slo_attainment"] * 100, f"n={base['n']}"),
        ("recovery/real_slo_attainment_chaos_pct",
         chaos["slo_attainment"] * 100,
         f"served={chaos['served']}/{chaos['n']}"),
        ("recovery/real_recovery_wall_s",
         led.get("ft_recovery_wall_median_s", 0.0),
         f"crashes={led.get('ft_crashes', 0.0):.0f},"
         f"restores={led.get('ft_restores', 0.0):.0f}"),
        ("recovery/real_readmitted_requests",
         led.get("ft_requests_readmitted", 0.0),
         f"requeued={led.get('ft_requests_requeued', 0.0):.0f},"
         f"shed={led.get('ft_requests_shed', 0.0):.0f}"),
        ("recovery/real_readmit_prefix_hit_pct",
         led.get("ft_readmit_prefix_hit_rate", 0.0) * 100,
         "warm re-prefill of prompt+emitted"),
    ]


def run() -> list:
    rows: list[Row] = []
    # Fig 13d: pre-compiled model loading, two storages, two models
    for storage, t_load in (("ssd", T_LOAD_SSD), ("sfs", T_LOAD_SFS)):
        total = T_CONNECT + t_load + T_HEALTH
        rows.append((f"recovery/substitute_ready_{storage}_s", total,
                     "connect+load+health(paper:minutes)"))

    # Fig 13c: fault -> substitute timeline
    meta = MetaStore()
    g = PDGroup("bench/g", "s", meta)
    g.setup(0.0, 4, 8)
    ml = MLOps(meta, NodeMonitor(seed=2, fault_rate_per_hour=0.0))
    rec = ml.recover(1000.0, g, g.members("D")[0], "device_reset")
    rows.append(("recovery/auto_recovery_s", rec.recovery_time,
                 f"ratio_after={g.ratio[0]}:{g.ratio[1]}"))

    # Fig 13b: tidal scaling events over one simulated day
    g2 = PDGroup("bench/tidal", "s", MetaStore())
    g2.setup(0.0, 2, 4)
    ml2 = MLOps(MetaStore())
    events = {"scale_out": 0, "scale_in": 0}
    t = 0.0
    while t < 86400.0:
        act = ml2.auto_scale(t, g2, base_rps=40.0,
                             rps_capacity_per_pair=11.0)
        if act:
            events[act] += 1
        t += 1800.0
    rows.append(("recovery/tidal_scale_out_events", events["scale_out"],
                 f"scale_in={events['scale_in']},peak_rate="
                 f"{tidal_rate(40.0, 43200.0):.1f}rps"))

    # §3.7 disaster recovery: a region fails mid-run, service continues
    from repro.configs import get_config
    from repro.core.cluster_sim import ClusterSim, SimConfig
    from repro.core.profiles import profile_for
    from repro.core.regions import Region, ServiceRouter
    from repro.core.requests import Scenario, WorkloadGenerator
    prof = profile_for(get_config("pangu-38b"))
    sc = Scenario("svc/x", "svc", 512, 2, 128, 32, 64, 16, 3.0)
    regions = [Region(n, {sc.name: ClusterSim(SimConfig(profile=prof),
                                              n_prefill=2, n_decode=4,
                                              policy="ondemand", seed=i)})
               for i, n in enumerate(("region-a", "region-b"))]
    router = ServiceRouter(regions, seed=0)
    gen = WorkloadGenerator([sc], base_rps=10, seed=6)
    m = router.run(gen.arrivals(40.0), 70.0, fail_at=20.0,
                   fail_region="region-a")
    rows.append(("recovery/region_failover_success_pct",
                 m["success_rate"] * 100,
                 f"dropped={m['dropped']},routed={m['routed']}"))

    # REAL engines: decode-node crash mid-stream + token-exact recovery
    rows.extend(_real_engine_rows())
    return rows
