"""Paper headline numbers: P/D-Serve vs aggregated serving (6.7x E2E
throughput) and vs the first disaggregated commercial version (+60%).

Three systems at the SAME total instance count:
  aggregated — both phases per instance, shared HBM, prefill stalls decode;
  disagg v1  — mixed pool, 1:1 ratio, queue-status scheduler, block-fixed
               transfer (the paper's baseline);
  P/D-Serve  — fine-grained per-scenario groups with Eq.1-profiled ratios,
               on-demand forwarding, block-free transfer.
"""
from __future__ import annotations

from benchmarks.common import Row
from repro.configs import get_config
from repro.core.aggregated import AggregatedSim
from repro.core.cluster_sim import ClusterSim, SimConfig, run_workload
from repro.core.perf_model import InstanceProfile, optimal_ratio
from repro.core.profiles import profile_for
from repro.core.requests import DEFAULT_SCENARIOS, WorkloadGenerator

TOTAL = 18
HORIZON = 90.0
LOAD = 120.0


def _workload(seed):
    gen = WorkloadGenerator(DEFAULT_SCENARIOS, base_rps=LOAD, seed=seed)
    return gen.arrivals(HORIZON)


def run() -> list:
    rows: list[Row] = []
    prof = profile_for(get_config("pangu-38b"))

    # ---- aggregated baseline
    agg = AggregatedSim(prof, n_instances=TOTAL, b_p=4, b_d=6, seed=1)
    m_agg = agg.run(_workload(21), HORIZON + 40)
    rows.append(("e2e/aggregated_rps", m_agg["throughput_rps"],
                 f"phi={m_agg['phi']:.3f}"))

    # ---- disaggregated v1: mixed pool, 1:1, baseline sched, block-fixed
    sim = ClusterSim(SimConfig(profile=prof, transfer_mode="block_fixed"),
                     n_prefill=TOTAL // 2, n_decode=TOTAL - TOTAL // 2,
                     policy="baseline", seed=2)
    m_v1 = run_workload(sim, _workload(22), HORIZON + 40)
    rows.append(("e2e/disagg_v1_rps", m_v1["throughput_rps"],
                 f"x{m_v1['throughput_rps']/max(m_agg['throughput_rps'],1e-9):.1f}_vs_agg,"
                 f"succ={m_v1['success_rate']:.2f}"))

    # ---- P/D-Serve: fine-grained groups, per-scenario Eq.1 ratio
    # allocate instances to scenarios by traffic weight, then split P/D
    # by the scenario's own profile (paper §3.3 "profiling in advance")
    wsum = sum(s.weight for s in DEFAULT_SCENARIOS)
    alloc = {}
    left = TOTAL
    for i, sc in enumerate(DEFAULT_SCENARIOS):
        n = max(2, round(TOTAL * sc.weight / wsum)) if i < 5 else max(2, left)
        n = min(n, left - 2 * (len(DEFAULT_SCENARIOS) - i - 1))
        alloc[sc.name] = n
        left -= n
    thr = 0.0
    ok = fail = 0
    ratios = []
    all_reqs = _workload(22)
    for sc in DEFAULT_SCENARIOS:
        n = alloc[sc.name]
        iprof = InstanceProfile(
            ttft_bs=prof.ttft(4 * (sc.prefix_len + sc.query_len_mean),
                              4 * sc.prefix_len * 0.9),
            b_p=4, r_pre=1.0, tpot_bs=prof.tpot(16), b_d=16,
            gen_tokens=sc.out_tokens_mean, xi=0.015)
        n_p, n_d = optimal_ratio(iprof, n)
        ratios.append(f"{sc.name.split('/')[1]}={n_p}:{n_d}")
        reqs = [r for r in all_reqs if r.scenario == sc.name]
        sim = ClusterSim(SimConfig(profile=prof, transfer_mode="block_free"),
                         n_prefill=n_p, n_decode=n_d, policy="ondemand",
                         seed=2)
        m = run_workload(sim, reqs, HORIZON + 40)
        thr += m["throughput_rps"]
        ok += m["completed"]
        fail += m["failed"]
    succ = ok / max(ok + fail, 1)
    x_agg = thr / max(m_agg["throughput_rps"], 1e-9)
    gain_v1 = (thr / max(m_v1["throughput_rps"], 1e-9) - 1) * 100
    rows.append(("e2e/pdserve_rps", thr,
                 f"succ={succ:.2f},{'|'.join(ratios)}"))
    rows.append(("e2e/pdserve_vs_aggregated_x", x_agg, "paper:6.7x"))
    rows.append(("e2e/pdserve_vs_v1_gain_pct", gain_v1, "paper:60pct"))
    rows.extend(_real_frontend_rows())
    return rows


def _real_frontend_rows() -> list:
    """Real-engine spot check: the multi-group frontend must serve a
    mixed-scenario workload token-identical to the single-group shim."""
    import numpy as np

    from repro.serving.cluster import MiniCluster, ServeRequest
    from repro.serving.frontend import ClusterFrontend

    cfg = get_config("granite-3-8b").reduced()

    def mk():
        rng = np.random.default_rng(7)
        return [ServeRequest(
            rid=i, scenario="svc/chat" if i % 2 == 0 else "svc/summ",
            tokens=list(rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(5, 12)))),
            max_new_tokens=3) for i in range(6)]

    fe = ClusterFrontend(cfg, topology={"svc/chat": (1, 1),
                                        "svc/summ": (1, 1)})
    multi = mk()
    fe.run(multi, max_ticks=80)
    mc = MiniCluster(cfg, n_prefill=2, n_decode=2, params=fe.params)
    base = mk()
    mc.run(base, max_ticks=80)
    match = all(a.generated == b.generated for a, b in zip(multi, base))
    return [
        ("e2e/real_frontend_done", float(sum(r.done for r in multi)),
         "of_6_across_2_scenario_groups"),
        ("e2e/real_frontend_token_parity", float(match),
         "vs_single_group_MiniCluster"),
        ("e2e/real_frontend_ticks", float(fe.tick_no),
         f"rejections={fe.rejections}"),
    ]
