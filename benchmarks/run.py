"""Benchmark harness: one module per paper table/figure.
Prints ``name,value,derived`` CSV. See DESIGN.md §7 for the figure map."""
from __future__ import annotations

import argparse
import sys

from benchmarks import (bench_decode, bench_e2e, bench_forwarding,
                        bench_goodput, bench_kernels, bench_open_loop,
                        bench_pd_ratio, bench_prefill, bench_prefix_cache,
                        bench_recovery, bench_spec, bench_transfer)
from benchmarks.common import emit

ALL = {
    "transfer": bench_transfer,       # Fig 4, 14c/d
    "forwarding": bench_forwarding,   # Fig 3b, 14a/b
    "pd_ratio": bench_pd_ratio,       # Fig 12, 13a
    "prefix": bench_prefix_cache,     # Fig 1b, 3a
    "e2e": bench_e2e,                 # 6.7x / 60% headline
    "decode": bench_decode,           # fused vs eager decode step
    "spec": bench_spec,               # fused speculative vs plain decode
    "prefill": bench_prefill,         # exact vs bucketed prefill compiles
    "recovery": bench_recovery,       # Fig 13b/c/d
    "kernels": bench_kernels,         # kernel microbench
    "open_loop": bench_open_loop,     # Poisson/tidal arrivals, TTFT/TPOT SLO
    "goodput": bench_goodput,         # autoscaler vs static SLO-goodput
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated subset")
    a = ap.parse_args(argv)
    picks = [s for s in a.only.split(",") if s] or list(ALL)
    print("name,value,derived")
    for name in picks:
        emit(ALL[name].run())
    return 0


if __name__ == "__main__":
    sys.exit(main())
