"""Measured steady-state decode step latency: eager per-layer loop vs
the fused jitted step (one donated device program per iteration).

The §2.2.3 disaggregation math assumes decode runs as fast as the
hardware allows; this section measures the real engines and emits
``BENCH_decode.json`` so the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import Row
from repro.configs import get_config

ARCHS = ["granite-3-8b", "jamba-1.5-large-398b"]
SLOTS = 4
WARMUP = 3
ITERS = 20
OUT_JSON = os.environ.get("BENCH_DECODE_JSON", "BENCH_decode.json")


def _engine(cfg, params, outs, *, fused):
    from repro.serving.engine import DecodeEngine
    from repro.serving.kvcache import PagedKVPool
    pool = PagedKVPool(cfg, num_blocks=96, block_size=4)
    de = DecodeEngine(cfg, params, pool, max_slots=SLOTS, fused=fused)
    for rid, out in enumerate(outs):
        pool.alloc(rid, out.prompt_len + WARMUP + ITERS + 4)
        if out.k is not None:
            pool.write_prefill(
                pool.owned(rid)[: (out.prompt_len + 3) // 4],
                out.k, out.v)
        de.admit(rid, out, pool.owned(rid))
    return de


def _steady_state_us(de) -> float:
    for _ in range(WARMUP):                 # JIT warm + table bucket
        de.step()
    t0 = time.perf_counter()
    for _ in range(ITERS):
        de.step()
    return (time.perf_counter() - t0) / ITERS * 1e6


def run() -> list:
    import jax

    from repro.models.modeling import decode_step_cache_size
    from repro.models.params import init_params
    from repro.serving.engine import PrefillEngine

    rows: list[Row] = []
    report = {}
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(4)
        prompts = [list(rng.integers(0, cfg.vocab_size, int(n)))
                   for n in rng.integers(8, 14, SLOTS)]
        outs = PrefillEngine(cfg, params).run(prompts)
        compiles0 = decode_step_cache_size()
        eager_us = _steady_state_us(
            _engine(cfg, params, outs, fused=False))
        fused_us = _steady_state_us(
            _engine(cfg, params, outs, fused=True))
        retraces = decode_step_cache_size() - compiles0
        speedup = eager_us / max(fused_us, 1e-9)
        tok_s = SLOTS / (fused_us / 1e6)
        short = arch.split("-")[0]
        rows += [
            (f"decode/{short}_eager_step_us", eager_us,
             f"slots={SLOTS}"),
            (f"decode/{short}_fused_step_us", fused_us,
             f"x{speedup:.1f}_vs_eager,retraces={retraces}"),
            (f"decode/{short}_fused_tok_s", tok_s, "steady_state"),
        ]
        report[arch] = {
            "eager_step_us": eager_us,
            "fused_step_us": fused_us,
            "speedup_x": speedup,
            "fused_tokens_per_s": tok_s,
            "fused_retraces": retraces,
            "slots": SLOTS,
            "iters": ITERS,
        }
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    return rows
