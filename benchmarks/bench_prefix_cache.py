"""Paper Fig. 1b + Fig. 3a: prefix-hit rate drives T_p, and fine-grained
per-scenario groups keep prefixes hot vs a mixed pool under the same HBM.

Two substrates: the cost-model rows (simulator) and a REAL-engine
section — cold vs warm suffix-only prefill through ClusterFrontend on a
repeated-prefix workload (paged-pool radix index, serving/kvcache.py)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row
from repro.configs import get_config
from repro.core.cluster_sim import ClusterSim, SimConfig, run_workload
from repro.core.profiles import profile_for
from repro.core.requests import DEFAULT_SCENARIOS, WorkloadGenerator


def _real_engine_rows() -> list:
    """Cold-vs-warm prefill wall time + hit rate on the real data path."""
    import jax
    from repro.models.params import init_params
    from repro.serving.cluster import ServeRequest
    from repro.serving.frontend import ClusterFrontend

    rows: list[Row] = []
    # sized so compute dominates eager dispatch on CPU (the stock
    # reduced() configs are dispatch-bound: suffix-only prefill saves
    # tokens but not wall time there)
    cfg = get_config("granite-3-8b").reduced().replace(
        d_model=512, d_ff=2048, num_layers=6, num_heads=8,
        num_kv_heads=4, head_dim=64, vocab_size=2048)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # repeated-prefix workload: 768-token shared prefix (= 48 whole
    # 16-token blocks) + per-request 32-token suffix, so every warm
    # forward has ONE stable suffix shape
    plen, slen = 768, 32
    prefix = list(map(int, rng.integers(0, cfg.vocab_size, plen)))
    prompts = [prefix + list(map(int, rng.integers(0, cfg.vocab_size,
                                                   slen)))
               for _ in range(5)]

    def serve(prefix_cache: bool):
        fe = ClusterFrontend(cfg, topology={"default": (1, 1)},
                             params=params, prefix_cache=prefix_cache,
                             prefill_kwargs={"num_blocks": 192},
                             decode_kwargs={"num_blocks": 96})
        for i, toks in enumerate(prompts):
            req = ServeRequest(rid=i, tokens=list(toks), max_new_tokens=2)
            fe.run([req], max_ticks=100)
        g = fe.groups["default"]
        # one prefill batch per sequential request, timed by the group
        return list(g.prefill_batch_s), g

    cold_s, _ = serve(False)
    warm_s, g = serve(True)
    # drop the JIT-warmup requests: cold[0] compiles the full-prompt
    # shape, warm[0] seeds the cache, warm[1] compiles the suffix shape
    cold = float(np.mean(cold_s[2:]))
    warm = float(np.mean(warm_s[2:]))
    pf = g.prefix_stats()
    rows.append(("prefix/real_cold_prefill_ms", cold * 1e3,
                 f"prompt={len(prompts[0])}tok"))
    rows.append(("prefix/real_warm_prefill_ms", warm * 1e3,
                 f"suffix_only={slen}tok"))
    rows.append(("prefix/real_warm_ttft_reduction_pct",
                 (1 - warm / max(cold, 1e-12)) * 100, "cold_vs_warm"))
    rows.append(("prefix/real_hit_rate", pf["hit_rate"] * 100,
                 f"reused_tokens={int(pf['reused_tokens'])}"))
    rows.append(("prefix/real_compute_tokens", pf["compute_tokens"],
                 f"vs_cold={sum(len(p) for p in prompts)}"))
    return rows


def _ssm_state_rows() -> list:
    """Cold-vs-warm prefill for the SSM/hybrid families (PR 6): warm
    hits restore a recurrent-state snapshot next to the prefix KV, so
    the measured section also surfaces the snapshot index counters
    (hits, resident bytes, restores) and the transfer scheduler's
    trailing state segments."""
    import jax
    from repro.models.params import init_params
    from repro.serving.cluster import ServeRequest
    from repro.serving.frontend import ClusterFrontend

    rows: list[Row] = []
    for arch, tag in (("mamba2-2.7b", "mamba2"),
                      ("jamba-1.5-large-398b", "jamba")):
        cfg = get_config(arch).reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        # prefix on a snapshot-stride boundary (lcm of SSD chunk, pool
        # block, capacity window) so every follow-up is a clean hit
        plen, slen = 96, 16
        prefix = list(map(int, rng.integers(0, cfg.vocab_size, plen)))
        prompts = [prefix + list(map(int, rng.integers(
            0, cfg.vocab_size, slen))) for _ in range(5)]

        def serve(prefix_cache: bool):
            fe = ClusterFrontend(cfg, topology={"default": (1, 1)},
                                 params=params, prefix_cache=prefix_cache,
                                 prefill_kwargs={"num_blocks": 64},
                                 decode_kwargs={"num_blocks": 64})
            for i, toks in enumerate(prompts):
                req = ServeRequest(rid=i, tokens=list(toks),
                                   max_new_tokens=2)
                fe.run([req], max_ticks=100)
            g = fe.groups["default"]
            return list(g.prefill_batch_s), g

        cold_s, _ = serve(False)
        warm_s, g = serve(True)
        cold = float(np.mean(cold_s[2:]))
        warm = float(np.mean(warm_s[2:]))
        pf = g.prefix_stats()
        ts = g.transfer_stats()
        rows.append((f"prefix/{tag}_cold_prefill_ms", cold * 1e3,
                     f"prompt={plen + slen}tok"))
        rows.append((f"prefix/{tag}_warm_prefill_ms", warm * 1e3,
                     f"suffix_only={slen}tok+state_restore"))
        rows.append((f"prefix/{tag}_snap_hit_rate",
                     100.0 * pf["snap_hits"] /
                     max(pf["snap_hits"] + pf["snap_misses"], 1),
                     f"restores={int(pf['state_restores'])}"))
        rows.append((f"prefix/{tag}_snap_resident_kb",
                     pf["snap_bytes"] / 1024.0,
                     f"stores={int(pf['snap_stores'])}"))
        rows.append((f"prefix/{tag}_state_segments",
                     ts["state_segments"],
                     f"payload={int(ts['state_payload_bytes'])}B"))
    return rows


def run() -> list:
    rows: list[Row] = []
    prof = profile_for(get_config("pangu-38b"))

    # Fig 1b: TTFT vs hit rate (direct from the cost model)
    batch_tokens = 4 * 2000
    for hit_pct in (0, 30, 50, 70, 90):
        hit_tokens = int(batch_tokens * hit_pct / 100)
        rows.append((f"prefix/ttft_at_{hit_pct}pct_hit",
                     prof.ttft(batch_tokens, hit_tokens) * 1e3, "ms"))

    # grouped vs mixed under one HBM budget
    budget = 48 * prof.kv_bytes_per_token * 1024
    horizon = 60.0

    def run_one(scenarios, n_p, n_d, seed):
        gen = WorkloadGenerator(scenarios, base_rps=24.0, seed=seed)
        reqs = gen.arrivals(horizon)
        sim = ClusterSim(SimConfig(profile=prof, hbm_prefix_budget=budget),
                         n_prefill=n_p, n_decode=n_d, seed=seed)
        return run_workload(sim, reqs, horizon + 20)

    mixed = run_one(DEFAULT_SCENARIOS, 6, 12, 9)
    fine = [run_one([sc], 1, 2, 9) for sc in DEFAULT_SCENARIOS]
    hit_f = sum(f["prefix_hit_rate"] for f in fine) / len(fine)
    thr_f = sum(f["throughput_rps"] for f in fine)
    ttft_f = sum(f["ttft_p50"] for f in fine) / len(fine)
    rows.append(("prefix/mixed_pool_hit_rate", mixed["prefix_hit_rate"] * 100,
                 f"ttft_p50={mixed['ttft_p50']:.3f}s"))
    rows.append(("prefix/fine_grained_hit_rate", hit_f * 100,
                 f"ttft_p50={ttft_f:.3f}s"))
    rows.append(("prefix/fine_grained_throughput_gain_pct",
                 (thr_f / max(mixed["throughput_rps"], 1e-9) - 1) * 100,
                 "grouped_vs_mixed"))

    # real engine: cold vs warm suffix-only prefill (serving data path)
    rows.extend(_real_engine_rows())
    # SSM/hybrid families: warm hits restore recurrent-state snapshots
    rows.extend(_ssm_state_rows())
    return rows
