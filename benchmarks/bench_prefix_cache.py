"""Paper Fig. 1b + Fig. 3a: prefix-hit rate drives T_p, and fine-grained
per-scenario groups keep prefixes hot vs a mixed pool under the same HBM."""
from __future__ import annotations

from benchmarks.common import Row
from repro.configs import get_config
from repro.core.cluster_sim import ClusterSim, SimConfig, run_workload
from repro.core.profiles import profile_for
from repro.core.requests import DEFAULT_SCENARIOS, WorkloadGenerator


def run() -> list:
    rows: list[Row] = []
    prof = profile_for(get_config("pangu-38b"))

    # Fig 1b: TTFT vs hit rate (direct from the cost model)
    batch_tokens = 4 * 2000
    for hit_pct in (0, 30, 50, 70, 90):
        hit_tokens = int(batch_tokens * hit_pct / 100)
        rows.append((f"prefix/ttft_at_{hit_pct}pct_hit",
                     prof.ttft(batch_tokens, hit_tokens) * 1e3, "ms"))

    # grouped vs mixed under one HBM budget
    budget = 48 * prof.kv_bytes_per_token * 1024
    horizon = 60.0

    def run_one(scenarios, n_p, n_d, seed):
        gen = WorkloadGenerator(scenarios, base_rps=24.0, seed=seed)
        reqs = gen.arrivals(horizon)
        sim = ClusterSim(SimConfig(profile=prof, hbm_prefix_budget=budget),
                         n_prefill=n_p, n_decode=n_d, seed=seed)
        return run_workload(sim, reqs, horizon + 20)

    mixed = run_one(DEFAULT_SCENARIOS, 6, 12, 9)
    fine = [run_one([sc], 1, 2, 9) for sc in DEFAULT_SCENARIOS]
    hit_f = sum(f["prefix_hit_rate"] for f in fine) / len(fine)
    thr_f = sum(f["throughput_rps"] for f in fine)
    ttft_f = sum(f["ttft_p50"] for f in fine) / len(fine)
    rows.append(("prefix/mixed_pool_hit_rate", mixed["prefix_hit_rate"] * 100,
                 f"ttft_p50={mixed['ttft_p50']:.3f}s"))
    rows.append(("prefix/fine_grained_hit_rate", hit_f * 100,
                 f"ttft_p50={ttft_f:.3f}s"))
    rows.append(("prefix/fine_grained_throughput_gain_pct",
                 (thr_f / max(mixed["throughput_rps"], 1e-9) - 1) * 100,
                 "grouped_vs_mixed"))
    return rows
