"""Paper Fig. 4 + Fig. 14c/d: block-fixed vs block-free D2D transfer.

Reports (a) modeled bandwidth utilization vs block size, (b) the D2D
transfer-time reduction of block-free mode (paper: 46%), (c) multi-hop
variance, and (d) wall-time of the real gather/RecvScatter kernels.
"""
from __future__ import annotations

import random

import numpy as np

from benchmarks.common import Row, timeit
from repro.configs import get_config
from repro.core.profiles import profile_for
from repro.core.transfer import KVTransferEngine, LinkModel


def run() -> list:
    rows: list[Row] = []
    prof = profile_for(get_config("pangu-38b"))
    link = LinkModel()
    eng = KVTransferEngine(link)
    layers = 40
    # a 2k-token prompt's KVCache
    nbytes = 2048 * prof.kv_bytes_per_token

    # Fig 4a/4b: utilization vs block granularity
    for blk_tokens in (16, 64, 256, 2048):
        bb = blk_tokens * prof.kv_bytes_per_token
        n_msgs = max(1, nbytes // bb) * layers
        util = link.utilization(nbytes, n_msgs)
        rows.append((f"transfer/util_block{blk_tokens}tok",
                     util * 100, "pct_bandwidth_util"))
    rows.append(("transfer/util_blockfree",
                 link.utilization(nbytes, 1) * 100, "pct_bandwidth_util"))

    # Fig 14c: mean transfer time, fixed vs free (46% reduction claim)
    t_fix = np.mean([eng.time_only(nbytes, block_bytes=16 *
                                   prof.kv_bytes_per_token, layers=layers,
                                   mode="block_fixed") for _ in range(50)])
    t_free = np.mean([eng.time_only(nbytes, block_bytes=16 *
                                    prof.kv_bytes_per_token, layers=layers,
                                    mode="block_free") for _ in range(50)])
    t_pl = np.mean([eng.time_only(nbytes, block_bytes=16 *
                                  prof.kv_bytes_per_token, layers=layers,
                                  mode="block_free", per_layer=True)
                    for _ in range(50)])
    red = (1 - t_free / t_fix) * 100
    rows.append(("transfer/block_fixed_ms", t_fix * 1e3, "mean_d2d_ms"))
    rows.append(("transfer/block_free_ms", t_free * 1e3,
                 f"reduction_{red:.0f}pct_vs_fixed(paper:46)"))
    rows.append(("transfer/per_layer_ms", t_pl * 1e3, "per_layer_trigger"))

    # Fig 10 trade-off: per-layer triggers overlap transfer with prefill
    # compute — only the LAST layer's transfer sits on the critical path —
    # at the cost of per-layer messages and model-revision (operator mode).
    t_prefill = prof.ttft(4 * 2048, 0)
    lat_whole = t_prefill + t_free
    per_layer_piece = t_pl / layers
    lat_overlap = max(t_prefill, t_pl - per_layer_piece) + per_layer_piece
    rows.append(("transfer/latency_whole_model_ms", lat_whole * 1e3,
                 "prefill_then_transfer"))
    rows.append(("transfer/latency_per_layer_overlap_ms", lat_overlap * 1e3,
                 f"saves_{(lat_whole-lat_overlap)*1e3:.1f}ms_ttfdt"))

    # Fig 14d: multi-hop conflict variance
    rng = random.Random(0)
    one = LinkModel(hops=1)
    multi = LinkModel(hops=3, conflict_prob=0.25)
    s1 = np.std([one.time(nbytes, 1, rng) for _ in range(400)])
    s2 = np.std([multi.time(nbytes, 1, rng) for _ in range(400)])
    rows.append(("transfer/stddev_1hop_ms", s1 * 1e3, "transfer_jitter"))
    rows.append(("transfer/stddev_multihop_ms", s2 * 1e3,
                 "conflicts_inflate_variance"))

    # real kernel wall time (interpret mode, CPU)
    import jax.numpy as jnp
    from repro.kernels import ops
    storage = jnp.zeros((8, 64, 16, 256), jnp.float32)
    idx = jnp.arange(32, dtype=jnp.int32)
    buf = jnp.ones((8, 32 * 16, 256), jnp.float32)
    rows.append(("kernels/kv_gather_us",
                 timeit(lambda: ops.kv_gather(storage, idx).block_until_ready()),
                 "interpret_mode"))
    rows.append(("kernels/kv_scatter_us",
                 timeit(lambda: ops.kv_scatter(storage, buf, idx)
                        .block_until_ready()), "interpret_mode"))
    return rows
