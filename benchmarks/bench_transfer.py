"""Paper Fig. 4 + Fig. 10 + Fig. 14c/d: block-fixed vs block-free D2D
transfer, and the overlapped per-layer pipeline on the REAL engine.

Reports (a) modeled bandwidth utilization vs block size, (b) the D2D
transfer-time reduction of block-free mode (paper: 46%), (c) the
MEASURED real-engine admission latency (prefill-done -> decode-admitted)
of blocking vs overlapped per-layer-triggered transfer, (d) multi-hop
variance, and (e) wall-time of the real gather/RecvScatter kernels.
"""
from __future__ import annotations

import random
import time

import numpy as np

from benchmarks.common import Row, timeit
from repro.configs import get_config
from repro.core.profiles import profile_for
from repro.core.transfer import KVTransferEngine, LinkModel


def run() -> list:
    rows: list[Row] = []
    prof = profile_for(get_config("pangu-38b"))
    link = LinkModel()
    eng = KVTransferEngine(link)
    layers = 40
    # a 2k-token prompt's KVCache
    nbytes = 2048 * prof.kv_bytes_per_token

    # Fig 4a/4b: utilization vs block granularity
    for blk_tokens in (16, 64, 256, 2048):
        bb = blk_tokens * prof.kv_bytes_per_token
        n_msgs = max(1, nbytes // bb) * layers
        util = link.utilization(nbytes, n_msgs)
        rows.append((f"transfer/util_block{blk_tokens}tok",
                     util * 100, "pct_bandwidth_util"))
    rows.append(("transfer/util_blockfree",
                 link.utilization(nbytes, 1) * 100, "pct_bandwidth_util"))

    # Fig 14c: mean transfer time, fixed vs free (46% reduction claim)
    t_fix = np.mean([eng.time_only(nbytes, block_bytes=16 *
                                   prof.kv_bytes_per_token, layers=layers,
                                   mode="block_fixed") for _ in range(50)])
    t_free = np.mean([eng.time_only(nbytes, block_bytes=16 *
                                    prof.kv_bytes_per_token, layers=layers,
                                    mode="block_free") for _ in range(50)])
    t_pl = np.mean([eng.time_only(nbytes, block_bytes=16 *
                                  prof.kv_bytes_per_token, layers=layers,
                                  mode="block_free", per_layer=True)
                    for _ in range(50)])
    red = (1 - t_free / t_fix) * 100
    rows.append(("transfer/block_fixed_ms", t_fix * 1e3, "mean_d2d_ms"))
    rows.append(("transfer/block_free_ms", t_free * 1e3,
                 f"reduction_{red:.0f}pct_vs_fixed(paper:46)"))
    rows.append(("transfer/per_layer_ms", t_pl * 1e3, "per_layer_trigger"))

    # Fig 10 trade-off, SHARED overlap model (LinkModel.per_layer_*):
    # per-layer triggers hide transfer behind layer compute — only the
    # residual the compute could not cover sits on the critical path.
    t_prefill = prof.ttft(4 * 2048, 0)
    lat_whole = t_prefill + t_free
    lat_overlap = link.per_layer_completion(nbytes, layers, t_prefill)
    rows.append(("transfer/latency_whole_model_ms", lat_whole * 1e3,
                 "prefill_then_transfer"))
    rows.append(("transfer/latency_per_layer_overlap_ms", lat_overlap * 1e3,
                 f"saves_{(lat_whole-lat_overlap)*1e3:.1f}ms_ttfdt"))
    rows.append(("transfer/per_layer_admission_tail_ms",
                 link.per_layer_tail(nbytes, layers, t_prefill) * 1e3,
                 "residual_after_prefill_done"))

    # Fig 14d: multi-hop conflict variance
    rng = random.Random(0)
    one = LinkModel(hops=1)
    multi = LinkModel(hops=3, conflict_prob=0.25)
    s1 = np.std([one.time(nbytes, 1, rng) for _ in range(400)])
    s2 = np.std([multi.time(nbytes, 1, rng) for _ in range(400)])
    rows.append(("transfer/stddev_1hop_ms", s1 * 1e3, "transfer_jitter"))
    rows.append(("transfer/stddev_multihop_ms", s2 * 1e3,
                 "conflicts_inflate_variance"))

    rows.extend(_real_engine_rows())

    # real kernel wall time (interpret mode, CPU)
    import jax.numpy as jnp
    from repro.kernels import ops
    storage = jnp.zeros((8, 64, 16, 256), jnp.float32)
    idx = jnp.arange(32, dtype=jnp.int32)
    buf = jnp.ones((8, 32 * 16, 256), jnp.float32)
    rows.append(("kernels/kv_gather_us",
                 timeit(lambda: ops.kv_gather(storage, idx).block_until_ready()),
                 "interpret_mode"))
    rows.append(("kernels/kv_scatter_us",
                 timeit(lambda: ops.kv_scatter(storage, buf, idx)
                        .block_until_ready()), "interpret_mode"))
    return rows


def _real_engine_rows() -> list:
    """MEASURED (not analytic) blocking vs overlapped transfer on the
    real serving path: same params, same prompts, token-identical
    output; admission latency (prefill-done -> decode-admitted, virtual
    link seconds) and TTFT must favor the pipeline, and the per-layer
    block-free wire must utilize no worse than the block-fixed
    baseline."""
    import jax
    from repro.models.params import init_params
    from repro.serving.cluster import MiniCluster, ServeRequest

    rows: list[Row] = []
    cfg = get_config("granite-3-8b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, 24)))
               for _ in range(6)]
    # a slower single-hop link so wire time is visible next to c_ctrl
    link = LinkModel(bandwidth=2e8, c_ctrl=5e-6)
    res = {}
    for overlap in (False, True):
        mc = MiniCluster(cfg, n_prefill=1, n_decode=2, params=params,
                         link=link, overlap_transfer=overlap)
        reqs = [ServeRequest(rid=i, tokens=list(p), max_new_tokens=4)
                for i, p in enumerate(prompts)]
        t0 = time.perf_counter()
        mc.run(reqs, max_ticks=200)
        wall = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        g = mc.frontend.groups["default"]
        tf = g.transfer_stats()
        label = "overlapped" if overlap else "blocking"
        res[label] = (tf, [list(r.generated) for r in reqs], wall, g)
        rows.append((f"transfer/real_admission_wait_{label}_us",
                     tf["admission_wait_mean_s"] * 1e6,
                     "prefill_done_to_decode_admitted"))
        rows.append((f"transfer/real_ttft_{label}_s",
                     float(np.mean(g.ttft_s)), "virtual_s_to_first_token"))
        rows.append((f"transfer/real_wall_{label}_s", wall, "e2e_wall"))
    assert res["overlapped"][1] == res["blocking"][1], "token parity broke"
    cut = (1 - res["overlapped"][0]["admission_wait_mean_s"]
           / max(res["blocking"][0]["admission_wait_mean_s"], 1e-12)) * 100
    rows.append(("transfer/real_admission_wait_cut_pct", cut,
                 "overlap_vs_blocking"))
    # wire utilization: overlapped per-layer messages vs the block-fixed
    # baseline moving the same bytes one block-layer message at a time
    tf = res["overlapped"][0]
    util_pl = (tf["link_bytes"] / link.bandwidth) \
        / max(tf["link_busy_s"], 1e-12) * 100
    g = res["overlapped"][3]
    layers = g.prefills[0].pool.attn_layers
    n_fixed = sum(job.n_kv_blocks * layers for job in g.sched.completed)
    util_fixed = link.utilization(int(tf["link_bytes"]),
                                  max(1, n_fixed)) * 100
    rows.append(("transfer/real_util_per_layer_pct", util_pl,
                 "overlapped_wire"))
    rows.append(("transfer/real_util_block_fixed_pct", util_fixed,
                 "baseline_same_bytes"))
    return rows
