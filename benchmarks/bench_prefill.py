"""Universal bucketed prefill: compile-stall + wall-time measurement.

Ragged tidal traffic against the exact-length path compiles one prefill
program per distinct (batch, length) shape; the bucketed path (now
serving EVERY family — SSM/hybrid and capacity MoE included, PR 5)
compiles O(num_buckets) and pays pad FLOPs instead. This section
measures both, per family, plus the warm prefix-reuse path where the
prefix KV length is bucketed too (traced q_offset), and emits
``BENCH_prefill.json`` so the compile-count trajectory is tracked
across PRs.
"""
from __future__ import annotations

import json
import os
import time
from typing import List

import numpy as np

from benchmarks.common import Row
from repro.configs import get_config

# dense baseline + the three families PR 4's gates kept on the
# exact-length path (capacity MoE / SSM / hybrid)
ARCHS = ["granite-3-8b", "qwen2-moe-a2.7b", "mamba2-2.7b",
         "jamba-1.5-large-398b"]
BATCH = 4
WAVES = 3
OUT_JSON = os.environ.get("BENCH_PREFILL_JSON", "BENCH_prefill.json")


def _workload(cfg, rng, n=BATCH * WAVES):
    lens = rng.integers(5, 14, n)
    return [list(map(int, rng.integers(0, cfg.vocab_size, int(ln))))
            for ln in lens]


def _run_waves(engine, prompts) -> List[float]:
    """Run the workload in BATCH-sized waves; per-wave wall seconds."""
    walls = []
    for i in range(0, len(prompts), BATCH):
        t0 = time.perf_counter()
        engine.run(prompts[i:i + BATCH])
        walls.append(time.perf_counter() - t0)
    return walls


def _phase(cfg, params, prompts, *, bucket):
    from repro.serving.engine import PrefillEngine, prefill_compile_count
    eng = PrefillEngine(cfg, params, bucket_prefill=bucket)
    c0 = prefill_compile_count()
    cold_walls = _run_waves(eng, prompts)      # includes compile stalls
    compiles = prefill_compile_count() - c0
    warm_walls = _run_waves(eng, prompts)      # steady state: shapes seen
    return {
        "compiles": compiles,
        "cold_total_s": sum(cold_walls),
        "steady_batch_median_s": float(np.median(warm_walls)),
        "pad_waste": eng.padded_tokens
        / max(eng.compute_tokens + eng.padded_tokens, 1),
    }


def _warm_phase(cfg, params, rng, *, bucket):
    """Warm prefix-reuse: suffix-only prefills across DISTINCT prefix
    lengths — exact mode retraces per prefix length, bucketed mode per
    (prefix bucket, suffix bucket) pair."""
    import jax.numpy as jnp

    from repro.serving.engine import PrefillEngine, prefill_compile_count
    eng = PrefillEngine(cfg, params, bucket_prefill=bucket)
    align = eng.prefix_align
    long = _workload(cfg, rng, 1)[0] + list(
        map(int, rng.integers(0, cfg.vocab_size, 60)))
    cold, = eng.run([long])
    plens = [16, 17, 20, 25, 28, 31] if align == 1 \
        else [align, 2 * align, 3 * align]
    c0 = prefill_compile_count()
    walls = []
    for plen in plens:
        pkv = jnp.concatenate([cold.k[:, :plen], cold.v[:, :plen]],
                              axis=-1)
        t0 = time.perf_counter()
        eng.run_suffix(long[plen:plen + 5], pkv)
        walls.append(time.perf_counter() - t0)
    return {
        "admissions": len(plens),
        "compiles": prefill_compile_count() - c0,
        "total_s": sum(walls),
        "batch_median_s": float(np.median(walls)),
    }


def run() -> list:
    import jax

    from repro.models.params import init_params

    rows: list[Row] = []
    report = {}
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(6)
        prompts = _workload(cfg, rng)
        exact = _phase(cfg, params, prompts, bucket=False)
        bucketed = _phase(cfg, params, prompts, bucket=True)
        short = arch.split("-")[0]
        rows += [
            (f"prefill/{short}_exact_compiles", exact["compiles"],
             f"cold_total_s={exact['cold_total_s']:.2f}"),
            (f"prefill/{short}_bucketed_compiles", bucketed["compiles"],
             f"cold_total_s={bucketed['cold_total_s']:.2f}"),
            (f"prefill/{short}_bucketed_batch_us",
             bucketed["steady_batch_median_s"] * 1e6,
             f"exact={exact['steady_batch_median_s'] * 1e6:.0f}us,"
             f"pad_waste={bucketed['pad_waste']:.2f}"),
        ]
        report[arch] = {"exact": exact, "bucketed": bucketed}
        eng_probe = None
        try:
            from repro.serving.engine import PrefillEngine
            eng_probe = PrefillEngine(cfg, params)
        except Exception:
            pass
        if eng_probe is not None and eng_probe.supports_prefix_reuse:
            w_ex = _warm_phase(cfg, params, np.random.default_rng(7),
                               bucket=False)
            w_bu = _warm_phase(cfg, params, np.random.default_rng(7),
                               bucket=True)
            rows.append((f"prefill/{short}_warm_compiles",
                         w_bu["compiles"],
                         f"exact={w_ex['compiles']},"
                         f"admissions={w_bu['admissions']}"))
            report[arch]["warm_prefix"] = {"exact": w_ex,
                                           "bucketed": w_bu}
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    return rows
