"""Paper Fig. 3b + Fig. 14a/b: on-demand forwarding vs queue-status
scheduler under growing workload (A -> 4A users). Paper: success rate gap
up to 42.3%, on-demand holds >= 99%."""
from __future__ import annotations

from benchmarks.common import Row
from repro.configs import get_config
from repro.core.cluster_sim import ClusterSim, SimConfig, run_workload
from repro.core.profiles import profile_for
from repro.core.requests import WorkloadGenerator


def run() -> list:
    rows: list[Row] = []
    prof = profile_for(get_config("pangu-38b"))
    A = 7.2    # calibrated: 4A sits at on-demand capacity (see EXPERIMENTS)
    horizon = 60.0
    for mult in (1, 2, 3, 4):
        out = {}
        for policy in ("ondemand", "baseline"):
            gen = WorkloadGenerator(base_rps=A * mult, seed=17)
            reqs = gen.arrivals(horizon)
            sim = ClusterSim(SimConfig(profile=prof), n_prefill=2,
                             n_decode=6, policy=policy, seed=3)
            out[policy] = run_workload(sim, reqs, horizon + 20)
        gap = (out["ondemand"]["success_rate"]
               - out["baseline"]["success_rate"]) * 100
        rows.append((f"forwarding/success_ondemand_{mult}A",
                     out["ondemand"]["success_rate"] * 100,
                     f"ttft_p99={out['ondemand']['ttft_p99']:.2f}s"))
        rows.append((f"forwarding/success_baseline_{mult}A",
                     out["baseline"]["success_rate"] * 100,
                     f"gap={gap:.1f}pct(paper:up_to_42.3)"))
    return rows
