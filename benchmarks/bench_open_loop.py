"""Open-loop arrival driver over the tickless serving core.

Closed-loop benchmarks (submit, drain, repeat) hide queueing: the paper's
production numbers are open-loop — requests arrive on their own clock
whether or not the cluster is keeping up. This section drives the
tickless ``ClusterFrontend`` with timestamped arrivals (``submit(at=t)``
+ ``serve()``): a steady Poisson process at moderate utilisation and a
tidal schedule (§2.1: off-peak -> burst -> off-peak) whose peak pushes
past the calibrated service rate. Reported per scenario: p50/p99 TTFT
and TPOT in *virtual seconds* and SLO attainment, plus
``BENCH_open_loop.json`` with the arrival schedule so the latency
trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import os
from typing import List

import numpy as np

from benchmarks.common import Row
from repro.configs import get_config

ARCH = "granite-3-8b"
TOPOLOGY = {"default": (2, 2)}            # 2 prefill, 2 decode
N_REQUESTS = 20
MAX_NEW = 4
UTIL = 0.6                                # steady-state target utilisation
# tidal: (fraction of requests, rate multiplier) — the burst exceeds the
# calibrated service rate, off-peak sits well under it
TIDAL_PHASES = [(0.35, 0.5), (0.30, 1.8), (0.35, 0.5)]
SLO_TTFT_X = 3.0                          # SLO = X * calibrated service time
SLO_TPOT_X = 3.0
OUT_JSON = os.environ.get("BENCH_OPEN_LOOP_JSON", "BENCH_open_loop.json")


def _prompts(cfg, rng, n, lo=6, hi=14):
    return [list(map(int, rng.integers(0, cfg.vocab_size,
                                       int(rng.integers(lo, hi)))))
            for _ in range(n)]


def _poisson_offsets(rng, rate: float, n: int) -> List[float]:
    return list(np.cumsum(rng.exponential(1.0 / rate, n)))


def _tidal_offsets(rng, base_rate: float, n: int) -> List[float]:
    """Inhomogeneous Poisson: per-phase exponential gaps."""
    ts, t = [], 0.0
    for frac, mult in TIDAL_PHASES:
        k = max(1, int(round(n * frac)))
        for gap in rng.exponential(1.0 / (base_rate * mult), k):
            t += gap
            ts.append(t)
    return ts[:n]


def _latencies(reqs):
    ttft = [r.first_token_t - r.submit_t for r in reqs]
    tpot = [(r.finish_t - r.first_token_t) / (len(r.generated) - 1)
            for r in reqs if len(r.generated) > 1]
    return ttft, tpot


def _scenario(fe, cfg, rng, offsets, *, ttft_slo, tpot_slo):
    from repro.serving.cluster import ServeRequest
    prompts = _prompts(cfg, rng, len(offsets))
    t0 = fe.now                            # keep arrivals on the shared clock
    reqs = [ServeRequest(rid=i, tokens=p, max_new_tokens=MAX_NEW)
            for i, p in enumerate(prompts)]
    for req, dt in zip(reqs, offsets):
        fe.submit(req, at=t0 + dt)
    fe.serve(watch=reqs)
    assert all(r.done for r in reqs)
    ttft, tpot = _latencies(reqs)
    ok = sum(1 for a, b in zip(ttft, tpot)
             if a <= ttft_slo and b <= tpot_slo)
    return {
        "n": len(reqs),
        "duration_s": max(r.finish_t for r in reqs) - t0,
        "ttft_p50_s": float(np.percentile(ttft, 50)),
        "ttft_p99_s": float(np.percentile(ttft, 99)),
        "tpot_p50_s": float(np.percentile(tpot, 50)),
        "tpot_p99_s": float(np.percentile(tpot, 99)),
        "slo_attainment": ok / len(reqs),
    }


def run() -> list:
    import jax

    from repro.models.params import init_params
    from repro.serving.frontend import ClusterFrontend

    cfg = get_config(ARCH).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    fe = ClusterFrontend(cfg, topology=TOPOLOGY, params=params)

    # calibrate: one request pays the JIT stalls, then three sequential
    # (queue-free) requests measure the warm virtual service time per
    # prefill batch / decode step
    rng = np.random.default_rng(5)
    warm = _prompts(cfg, rng, 4)
    from repro.serving.cluster import ServeRequest
    wreqs = [ServeRequest(rid=1000 + i, tokens=p, max_new_tokens=MAX_NEW)
             for i, p in enumerate(warm)]
    for req in wreqs:
        fe.run([req])
    w_ttft, w_tpot = _latencies(wreqs[1:])
    svc = float(np.median(w_ttft))         # warm: batch + transfer
    step = float(np.median(w_tpot))
    n_prefill = len(TOPOLOGY["default"]) and TOPOLOGY["default"][0]
    base_rate = UTIL * n_prefill / max(svc, 1e-9)
    ttft_slo, tpot_slo = SLO_TTFT_X * svc, SLO_TPOT_X * step

    report = {
        "arch": ARCH,
        "topology": {k: list(v) for k, v in TOPOLOGY.items()},
        "calibration": {"service_s": svc, "step_s": step,
                        "rate_rps": base_rate, "util": UTIL},
        "slo": {"ttft_s": ttft_slo, "tpot_s": tpot_slo},
        "scenarios": {},
    }
    rows: list[Row] = []
    schedules = {
        "steady": _poisson_offsets(np.random.default_rng(11), base_rate,
                                   N_REQUESTS),
        "tidal": _tidal_offsets(np.random.default_rng(12), base_rate,
                                N_REQUESTS),
    }
    for name, offsets in schedules.items():
        res = _scenario(fe, cfg, np.random.default_rng(13), offsets,
                        ttft_slo=ttft_slo, tpot_slo=tpot_slo)
        res["arrival_offsets_s"] = [round(t, 6) for t in offsets]
        if name == "tidal":
            res["phases"] = [{"frac": f, "rate_mult": m}
                             for f, m in TIDAL_PHASES]
        report["scenarios"][name] = res
        rows += [
            (f"open_loop/{name}_ttft_p50_s", res["ttft_p50_s"],
             f"p99={res['ttft_p99_s']:.4f}s"),
            (f"open_loop/{name}_tpot_p50_s", res["tpot_p50_s"],
             f"p99={res['tpot_p99_s']:.4f}s"),
            (f"open_loop/{name}_slo_attainment", res["slo_attainment"],
             f"ttft_slo={ttft_slo:.3f}s,tpot_slo={tpot_slo:.4f}s"),
        ]
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    return rows
