"""Fused speculative decode vs plain fused greedy decode.

Greedy speculation is lossless, so the interesting numbers are purely
throughput-side: tokens emitted per jitted step (the speculation
speedup — a perfect draft retires k+1 tokens per verification sweep)
and the steady-state per-token latency (TPOT), spec vs plain, on the
same engines the serving path uses. Emits ``BENCH_spec.json`` so the
trajectory is tracked across PRs.

A perfect draft (the target drafting for itself) is used so acceptance
— and therefore the steps-per-token ratio — is deterministic; real
deployments swap in a distilled checkpoint and land between 1x and the
k+1 ceiling depending on draft quality.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import Row
from repro.configs import get_config

ARCHS = ["granite-3-8b", "mamba2-2.7b"]
SLOTS = 4
K = 3
WARMUP = 3
ITERS = 12
OUT_JSON = os.environ.get("BENCH_SPEC_JSON", "BENCH_spec.json")


def _engine(cfg, params, outs, prompts, *, spec):
    from repro.serving.engine import DecodeEngine
    from repro.serving.kvcache import PagedKVPool
    pool = PagedKVPool(cfg, num_blocks=192, block_size=4)
    room = (WARMUP + ITERS) * (K + 1) + 4
    de = DecodeEngine(cfg, params, pool, max_slots=SLOTS, spec=spec)
    for rid, out in enumerate(outs):
        pool.alloc(rid, out.prompt_len + room)
        if out.k is not None:
            pool.write_prefill(
                pool.owned(rid)[: (out.prompt_len + 3) // 4],
                out.k, out.v)
        de.admit(rid, out, pool.owned(rid),
                 prompt=prompts[rid] if spec is not None else None)
    return de


def _steady_state(de):
    """(step latency us, emitted tokens per step) once warm."""
    for _ in range(WARMUP):                 # JIT warm + table bucket
        de.step()
    emitted = 0
    t0 = time.perf_counter()
    for _ in range(ITERS):
        for toks in de.step().values():
            emitted += len(toks) if isinstance(toks, list) else 1
    step_us = (time.perf_counter() - t0) / ITERS * 1e6
    return step_us, emitted / ITERS


def run() -> list:
    import jax

    from repro.models.params import init_params
    from repro.serving.engine import PrefillEngine
    from repro.serving.speculative import SpecConfig

    rows: list[Row] = []
    report = {}
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(4)
        prompts = [list(map(int, rng.integers(0, cfg.vocab_size, int(n))))
                   for n in rng.integers(8, 14, SLOTS)]
        outs = PrefillEngine(cfg, params).run(prompts)
        plain_us, plain_tps = _steady_state(
            _engine(cfg, params, outs, prompts, spec=None))
        spec = SpecConfig(cfg, params, k=K)     # perfect draft: ceiling
        spec_us, spec_tps = _steady_state(
            _engine(cfg, params, outs, prompts, spec=spec))
        # steps-per-token ratio: how many plain steps one spec step
        # replaces (K+1 at the perfect-draft ceiling)
        steps_ratio = (spec_tps / SLOTS) / (plain_tps / SLOTS)
        plain_tpot = plain_us / plain_tps
        spec_tpot = spec_us / spec_tps
        short = arch.split("-")[0]
        rows += [
            (f"spec/{short}_plain_tpot_us", plain_tpot,
             f"slots={SLOTS}"),
            (f"spec/{short}_spec_tpot_us", spec_tpot,
             f"k={K},x{plain_tpot / max(spec_tpot, 1e-9):.1f}_vs_plain"),
            (f"spec/{short}_steps_per_token_x", steps_ratio,
             f"ceiling={K + 1}"),
        ]
        report[arch] = {
            "plain_step_us": plain_us,
            "spec_step_us": spec_us,
            "plain_tokens_per_step": plain_tps,
            "spec_tokens_per_step": spec_tps,
            "steps_per_token_x": steps_ratio,
            "plain_tpot_us": plain_tpot,
            "spec_tpot_us": spec_tpot,
            "tpot_speedup_x": plain_tpot / max(spec_tpot, 1e-9),
            "k": K,
            "slots": SLOTS,
            "iters": ITERS,
        }
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    return rows
