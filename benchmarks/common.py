"""Shared benchmark helpers. Every bench returns rows:
(name, us_per_call_or_metric, derived_string)."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

Row = Tuple[str, float, str]


def timeit(fn: Callable, *, warmup: int = 1, iters: int = 5) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6  # us


def emit(rows: List[Row]):
    for name, val, derived in rows:
        print(f"{name},{val:.3f},{derived}")
