"""Kernel microbenchmarks: Pallas (interpret on CPU) vs pure-jnp refs.

On-TPU these compile natively; interpret-mode wall times only prove the
code path runs — roofline terms come from the dry-run, not from here.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timeit
from repro.kernels import ops, ref


def run() -> list:
    rows: list[Row] = []
    rng = np.random.default_rng(0)
    L, NB, BS, kvd, hd = 8, 128, 16, 256, 64
    storage = jnp.asarray(rng.normal(size=(L, NB, BS, 2 * kvd)), jnp.float32)
    idx = jnp.asarray(rng.permutation(NB)[:64], jnp.int32)
    buf = jnp.asarray(rng.normal(size=(L, 64 * BS, 2 * kvd)), jnp.float32)
    pages = storage[0]
    B, MAXB = 8, 8
    q = jnp.asarray(rng.normal(size=(B, (kvd // hd) * 4, hd)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, NB, (B, MAXB)), jnp.int32)
    lens = jnp.asarray(rng.integers(1, MAXB * BS, B), jnp.int32)

    pairs = [
        ("kv_gather", lambda: ops.kv_gather(storage, idx),
         lambda: ref.kv_gather(storage, idx)),
        ("kv_scatter", lambda: ops.kv_scatter(storage, buf, idx),
         lambda: ref.kv_scatter(storage, buf, idx)),
        ("paged_attention", lambda: ops.paged_attention(q, pages, bt, lens),
         lambda: ref.paged_attention(q, pages, bt, lens)),
    ]
    for name, k_fn, r_fn in pairs:
        t_k = timeit(lambda: k_fn().block_until_ready(), iters=3)
        t_r = timeit(lambda: r_fn().block_until_ready(), iters=3)
        rows.append((f"kernels/{name}_pallas_us", t_k, "interpret_mode"))
        rows.append((f"kernels/{name}_ref_us", t_r, "jnp_oracle"))
    return rows
