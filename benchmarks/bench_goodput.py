"""SLO-goodput under tidal overload: autoscaler vs best static split.

A two-burst tidal day on the REAL tickless serving path — a
prefill-bound burst (long prompts, short outputs) then a decode-bound
burst (short prompts, long outputs) — drives (a) every static
(n_p, n_d) split of a fixed node budget and (b) a small base topology
plus the overload-robust autoscaler leasing heterogeneous spares
(prefill-heavy / decode-heavy) from a shared pool, with chunked-prefill
absorption enabled. Goodput is DistServe-style: requests meeting BOTH
the TTFT and TPOT SLO per second of makespan — raw throughput earns
nothing once latency blows the SLO.

Acceptance: autoscaler goodput >= the best static split, only
past-deadline requests shed, and every served request token-identical
to an uncontended fault-free reference. Writes ``BENCH_goodput.json``.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import Row

ARCH = "granite-3-8b"
BUDGET = 4                          # total nodes for every contender
BASE = (1, 1)                       # autoscaler's always-on topology
POOL = {"prefill-heavy": 1, "decode-heavy": 1}
SLO_TTFT_S = 0.08
SLO_TPOT_S = 0.02
DEADLINE_S = 0.15                   # shed fast: TTFT SLO + margin
PROVISION_SCALE = 0.001             # compressed Fig. 13 timeline
OUT_JSON = os.environ.get("BENCH_GOODPUT_JSON", "BENCH_goodput.json")


def _tidal_requests(cfg, rng):
    """Warm trickle, prefill-bound burst, decode-bound burst, then a
    prefill-complete scoring burst (max_new=0: the decode side is idle,
    so chunked-prefill absorption is the only extra capacity left once
    the pool is spent)."""
    reqs = []

    def add(n, t0, rate, *, lo, hi, max_new):
        t = t0
        for _ in range(n):
            reqs.append((t, list(map(int, rng.integers(
                0, cfg.vocab_size, int(rng.integers(lo, hi))))), max_new))
            t += 1.0 / rate
        return t

    add(4, 0.0, 20.0, lo=6, hi=10, max_new=3)          # warm trickle
    add(240, 0.25, 80.0, lo=20, hi=28, max_new=2)      # prefill tide
    add(150, 3.60, 50.0, lo=5, hi=9, max_new=24)       # decode tide
    add(110, 7.20, 110.0, lo=20, hi=28, max_new=0)     # scoring tide
    add(4, 8.45, 20.0, lo=6, hi=10, max_new=3)         # cool-down
    return reqs


def run() -> list:
    import jax

    from repro.configs import get_config
    from repro.core.mlops import SLOSpec
    from repro.models.params import init_params
    from repro.serving.autoscale import AutoScaler, NodePool
    from repro.serving.cluster import ServeRequest
    from repro.serving.faults import DeterministicService
    from repro.serving.frontend import ClusterFrontend

    cfg = get_config(ARCH).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    svc = DeterministicService(prefill_base_s=0.02,
                               prefill_per_token_s=5e-4,
                               decode_base_s=4e-3)
    rng = np.random.default_rng(17)
    schedule = _tidal_requests(cfg, rng)

    def _mk(topology, *, scaled=False):
        fe = ClusterFrontend(
            cfg, topology={"default": topology}, params=params,
            prefill_kwargs={"batch_size": 1},
            decode_kwargs={"max_slots": 4},
            service_model=svc, absorb_prefill=scaled)
        sc = pool = None
        if scaled:
            pool = NodePool(dict(POOL), provision_scale=PROVISION_SCALE)
            sc = AutoScaler(fe, pool,
                            SLOSpec(ttft_s=SLO_TTFT_S, tpot_s=SLO_TPOT_S),
                            period_s=0.05, window_s=0.15, cooldown_s=0.02)
        return fe, pool, sc

    def _drive(topology, *, scaled=False, deadline=DEADLINE_S):
        fe, pool, sc = _mk(topology, scaled=scaled)
        reqs = [ServeRequest(rid=i, tokens=toks, max_new_tokens=m,
                             slo_deadline_s=deadline)
                for i, (_, toks, m) in enumerate(schedule)]
        for req, (t, _, _) in zip(reqs, schedule):
            fe.submit(req, at=t)
        fe.serve(watch=reqs, max_events=2_000_000)
        fe.serve(max_events=400_000)       # drain scale/drain events
        served = [r for r in reqs if r.done and not r.shed]
        ok = 0
        for r in served:
            ttft = r.first_token_t - r.submit_t
            tpot = ((r.finish_t - r.first_token_t)
                    / max(len(r.generated) - 1, 1))
            ok += int(ttft <= SLO_TTFT_S and tpot <= SLO_TPOT_S)
        span = max(r.finish_t for r in served) - schedule[0][0]
        shed = [r for r in reqs if r.shed]
        # only past-deadline requests may shed
        late_only = all(r.finish_t >= r.submit_t + deadline - 1e-9
                        for r in shed)
        out = {
            "goodput_rps": ok / max(span, 1e-9),
            "slo_met": ok, "served": len(served), "shed": len(shed),
            "n": len(reqs), "late_only_sheds": late_only,
            "makespan_s": span,
        }
        if scaled:
            st = fe.groups["default"].transfer_stats()
            out["scale"] = {k: st[k] for k in st
                            if k.startswith("scale_")}
            out["absorb"] = dict(fe.groups["default"].absorbs)
            out["pool"] = pool.ledger()
            out["gateway"] = fe.gateway_stats()
        return out, {r.rid: tuple(r.generated) for r in served}

    # uncontended reference: big static cluster, no deadline pressure
    _, golden = _drive((BUDGET, BUDGET), deadline=-1.0)

    static = {}
    best_name, best = None, None
    for n_p in range(1, BUDGET):
        n_d = BUDGET - n_p
        res, toks = _drive((n_p, n_d))
        assert all(golden[rid] == t for rid, t in toks.items())
        static[f"{n_p}p{n_d}d"] = res
        if best is None or res["goodput_rps"] > best["goodput_rps"]:
            best_name, best = f"{n_p}p{n_d}d", res

    auto, toks = _drive(BASE, scaled=True)
    token_identity = all(golden[rid] == t for rid, t in toks.items())

    report = {
        "arch": ARCH,
        "budget_nodes": BUDGET,
        "base_topology": list(BASE),
        "pool": POOL,
        "slo": {"ttft_s": SLO_TTFT_S, "tpot_s": SLO_TPOT_S,
                "deadline_s": DEADLINE_S},
        "static": static,
        "best_static": best_name,
        "autoscaler": auto,
        "token_identity_vs_reference": token_identity,
        "acceptance": {
            "goodput_ge_best_static":
                auto["goodput_rps"] >= best["goodput_rps"] - 1e-9,
            "only_past_deadline_shed": bool(
                auto["late_only_sheds"]
                and all(s["late_only_sheds"] for s in static.values())),
            "token_identity": token_identity,
        },
    }
    with open(OUT_JSON, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)

    rows: list[Row] = [
        ("goodput/autoscaler_rps", auto["goodput_rps"],
         f"slo_met={auto['slo_met']}/{auto['n']},shed={auto['shed']}"),
        ("goodput/best_static_rps", best["goodput_rps"],
         f"{best_name},slo_met={best['slo_met']}/{best['n']},"
         f"shed={best['shed']}"),
        ("goodput/autoscaler_vs_static_x",
         auto["goodput_rps"] / max(best["goodput_rps"], 1e-9),
         "acceptance >= 1.0"),
        ("goodput/absorbed_chunks", auto["absorb"]["absorb_chunks"],
         f"requests={auto['absorb']['absorb_requests']}"),
        ("goodput/scale_ups", auto["scale"]["scale_up_done"],
         f"downs={auto['scale']['scale_down_done']},"
         f"denied={auto['scale']['scale_denied']}"),
        ("goodput/token_identity", float(token_identity),
         "served streams == uncontended reference"),
    ]
    return rows
